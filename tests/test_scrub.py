"""Configuration-memory scrubbing: readback -> CRC verify -> heal.

The resilience claim under test (ISSUE 5 acceptance bar): an SEU injected
via ``server.inject_seu`` during a live stream is *detected* (CRC
mismatch against the golden store, or a disagreement spike steering the
scrubber there) and *healed* within one configured scrub interval, on
both backends and on both kernel routings (banded and dense), with zero
wrong outputs under single-fault TMR conditions. Scrubbing is the third
leg of the TMR story: the vote masks, the readback+CRC detects, the
golden re-encode repairs — without it a second upset in the same logical
LUT is fatal (tests/test_seu.py's double-fault controls).

Property tests (tests/_propshim):
  * readback round-trip — a clean stack's readback verifies against the
    golden digests on every slot/replica, and ANY injected flip changes
    exactly one replica's CRC;
  * scheduler fairness — every replica frame is scrubbed within one full
    round-robin cycle regardless of how hard steering pulls elsewhere.
"""
import importlib.util
import json
import os

import numpy as np
import pytest

from repro.core.bdt import GradientBoostedClassifier
from repro.core.bitstream import GoldenImageStore, table_digest
from repro.core.fabric import FabricSim, MultiFabricSim, packed_table_image
from repro.core.readout import ReadoutChip
from repro.core.tmr import (
    N_REPLICAS,
    inject_seu,
    replica_table_images,
    replicate_config,
)
from repro.data.smartpixel import SmartPixelConfig, generate, train_test_split
from repro.launch.readout_server import (
    DEFAULT_SCRUB_INTERVAL,
    ReadoutServer,
    ServerConfig,
)
from tests._propshim import given, settings, strategies as st


# ------------------------------------------------------------ fixtures
@pytest.fixture(scope="module")
def duo():
    """Two small calibrated chips (28nm + 130nm), a feature batch and the
    training split — shared by every server-driving test here."""
    d = generate(SmartPixelConfig(n_events=10_000, seed=23))
    tr, te = train_test_split(d)
    chips = []
    for fabric, depth in (("efpga_28nm", 3), ("efpga_130nm", 3)):
        clf = GradientBoostedClassifier(
            n_estimators=1, max_depth=depth, max_leaf_nodes=5,
            min_samples_leaf=300,
        ).fit(tr["features"], tr["label"])
        chip = ReadoutChip.build(clf, fabric=fabric)
        chip.calibrate(tr["features"], tr["label"], target_sig_eff=0.95)
        chips.append(chip)
    return chips, te["features"][:48]


def _golden(chip, X):
    return chip.golden.decision_function_raw(chip.golden.quantize_features(X))


def _serve(server, X, chip_slot=0):
    server.submit_batch(chip_slot, X)
    res = sorted(server.flush(), key=lambda r: r.seq)
    return np.array([r.score_raw for r in res])


def _effective_flip(chip, X):
    """(lut, bit) in BASE coordinates whose flip changes the outputs."""
    golden = _golden(chip, X)
    bits = chip.encode_features(X)
    for li in range(chip.config.n_luts):
        for bi in range(16):
            outs, _ = FabricSim(inject_seu(chip.config, li, bi)).run(bits)
            if not np.array_equal(
                    chip.synth.decode_outputs(np.asarray(outs)), golden):
                return li, bi
    raise AssertionError("no effective flip found (degenerate chip?)")


# -------------------------------------------- readback round-trip (prop)
@given(seed=st.integers(0, 10_000))
@settings(max_examples=15, deadline=None)
def test_flip_changes_exactly_one_crc(seed, _cache={}):
    """Golden-store property: a clean image set verifies everywhere; ANY
    single injected flip changes exactly one replica's CRC digest."""
    if "chip" not in _cache:
        d = generate(SmartPixelConfig(n_events=8_000, seed=5))
        tr, _ = train_test_split(d)
        clf = GradientBoostedClassifier(
            n_estimators=1, max_depth=3, max_leaf_nodes=5,
            min_samples_leaf=300).fit(tr["features"], tr["label"])
        _cache["chip"] = ReadoutChip.build(clf)
    cfg = _cache["chip"].config
    L = max(len(cfg.level_sizes), 1)
    m_pad = -(-max(cfg.level_sizes, default=1) // 128) * 128
    store = GoldenImageStore()
    store.register(0, cfg, replica_table_images(cfg, L, m_pad))
    # clean round-trip
    for r in range(N_REPLICAS):
        img = packed_table_image(replicate_config(cfg, r), L, m_pad)
        assert store.verify(0, r, img), r
    rng = np.random.default_rng(seed)
    victim = int(rng.integers(0, N_REPLICAS))
    li = int(rng.integers(0, cfg.n_luts))
    bi = int(rng.integers(0, 16))
    bad = inject_seu(replicate_config(cfg, victim), li, bi)
    ok = [
        store.verify(0, r, packed_table_image(
            bad if r == victim else replicate_config(cfg, r), L, m_pad))
        for r in range(N_REPLICAS)
    ]
    assert ok == [r != victim for r in range(N_REPLICAS)], (victim, ok)


def test_readback_matches_golden_clean_stack(duo):
    """Device readback == golden image on a freshly packed stack, for
    every slot and replica, banded AND dense, redundant and plain — the
    structural identity the scrub loop's detection rests on."""
    from repro.kernels.lut_eval import ops as lut_ops

    chips, _ = duo
    configs = [c.config for c in chips]
    for band in (None, False):
        for redundancy in ("tmr", "none"):
            stack = lut_ops.pack_fabrics(
                configs, band=band, redundancy=redundancy)
            for slot, cfg in enumerate(configs):
                imgs = replica_table_images(
                    cfg, stack.n_levels, stack.m_pad, stack.n_replicas)
                rb = stack.readback_chip(slot)
                assert rb.shape[0] == stack.n_replicas
                for r in range(stack.n_replicas):
                    np.testing.assert_array_equal(
                        stack.readback_replica(slot, r), imgs[r],
                        err_msg=f"band={band} red={redundancy} "
                                f"slot={slot} r={r}")
                    assert table_digest(rb[r]) == table_digest(imgs[r])


def test_readback_and_twin_agree_across_backends(duo):
    """The host-oracle scrub twin (MultiFabricSim.readback_tables) and
    the device readback return byte-identical images, so one golden
    digest set serves both backends."""
    from repro.kernels.lut_eval import ops as lut_ops

    chips, _ = duo
    configs = [c.config for c in chips]
    stack = lut_ops.pack_fabrics(configs, redundancy="tmr")
    reps = [replicate_config(c, r) for c in configs for r in range(3)]
    sim = MultiFabricSim(reps)
    for slot in range(len(configs)):
        for r in range(3):
            np.testing.assert_array_equal(
                stack.readback_replica(slot, r),
                sim.readback_tables(slot * 3 + r, stack.n_levels,
                                    stack.m_pad))


def test_readback_bounds(duo):
    from repro.kernels.lut_eval import ops as lut_ops

    chips, _ = duo
    stack = lut_ops.pack_fabrics([chips[0].config], redundancy="tmr")
    with pytest.raises(ValueError, match="slot"):
        stack.readback_replica(1, 0)
    with pytest.raises(ValueError, match="replica"):
        stack.readback_replica(0, 3)
    sim = MultiFabricSim([chips[0].config])
    with pytest.raises(ValueError, match="index"):
        sim.readback_tables(5, stack.n_levels, stack.m_pad)


# ----------------------------------------------------- config validation
def test_serverconfig_scrub_validation():
    ServerConfig(scrub_interval=DEFAULT_SCRUB_INTERVAL)          # valid
    ServerConfig(scrub_interval=None, scrub_mode="round_robin")  # valid
    for bad in (0, -1, 1.5, "4", True):
        with pytest.raises(ValueError, match="scrub_interval"):
            ServerConfig(scrub_interval=bad)
    with pytest.raises(ValueError, match="scrub_mode"):
        ServerConfig(scrub_mode="psychic")


# ------------------------------------------------------------ scheduling
def test_scrub_runs_every_interval_dispatches(duo):
    """interval=k => exactly one scrub step per k scoring dispatches,
    interleaved by the event loop itself (no manual scrub calls)."""
    chips, X = duo
    srv = ReadoutServer([chips[0]], ServerConfig(
        max_batch=16, max_latency_s=1e9, backend="host",
        redundancy="tmr", scrub_interval=3, pipeline_depth=1))
    for _ in range(7):
        _serve(srv, X[:16])     # one dispatch each
    rep = srv.report()["scrub"]
    assert rep["enabled"] and rep["interval"] == 3
    assert rep["steps"] == 2, rep   # dispatches 3 and 6
    # round-robin pointer advanced 2 of 3 frames, no full cycle yet
    assert rep["cycles"] == 0 and rep["frames_scrubbed"] == 2


@given(hot=st.integers(0, 5), seed=st.integers(0, 1000))
@settings(max_examples=8, deadline=None)
def test_scrub_fairness_under_steering(hot, seed, _cache={}):
    """Fairness property: however hard the steered mode pulls toward one
    hot frame, one full cycle of scrub steps still scrubs EVERY frame at
    least once (the round-robin turn always advances)."""
    if "duo" not in _cache:
        d = generate(SmartPixelConfig(n_events=8_000, seed=29))
        tr, _ = train_test_split(d)
        clf = GradientBoostedClassifier(
            n_estimators=1, max_depth=3, max_leaf_nodes=5,
            min_samples_leaf=300).fit(tr["features"], tr["label"])
        _cache["duo"] = [ReadoutChip.build(clf), ReadoutChip.build(clf)]
    srv = ReadoutServer(list(_cache["duo"]), ServerConfig(
        max_batch=16, max_latency_s=1e9, backend="host",
        redundancy="tmr", scrub_interval=1, scrub_mode="steered"))
    n_frames = srv.n_chips * srv.n_replicas
    rng = np.random.default_rng(seed)
    for _ in range(n_frames):
        # keep one frame's health counter climbing every step so steering
        # fires maximally often — fairness must hold anyway
        srv._stats[hot // 3].disagreements[hot % 3] += int(
            rng.integers(1, 50))
        srv.scrub_step()
    rep = srv.report()["scrub"]
    assert rep["cycles"] == 1
    assert all(n >= 1 for n in rep["per_frame_scrubs"]), rep
    assert rep["detections"] == 0   # steering alone never "heals" clean


# ------------------------------------------- detect + heal, live streams
def test_steered_scrub_heals_within_one_interval(duo):
    """THE steering claim: after the faulty dispatch's counters fold, the
    very next scrub step repairs the upset — no waiting for the faulty
    frame's round-robin turn (it is deliberately the LAST rr frame)."""
    chips, X = duo
    golden = [_golden(c, X) for c in chips]
    li, bi = _effective_flip(chips[1], X)
    srv = ReadoutServer(list(chips), ServerConfig(
        max_batch=len(X) * 2, max_latency_s=1e9, backend="host",
        redundancy="tmr", scrub_interval=1, scrub_mode="steered",
        pipeline_depth=1))
    for slot in range(2):
        np.testing.assert_array_equal(_serve(srv, X, slot), golden[slot])
    # upset the LAST round-robin frame (chip 1, replica 2) so round-robin
    # alone could not reach it for another 4 steps
    from repro.core.tmr import replica_lut_index
    srv.inject_seu(1, 2, replica_lut_index(chips[1].config, 2, li), bi)
    assert not srv.verify_frame(1, 2)
    steps_before = srv.report()["scrub"]["steps"]
    # dispatch 1: scores against the faulty arrays — voted output stays
    # golden (single fault), the replica-2 counter climbs at drain
    np.testing.assert_array_equal(_serve(srv, X, 1), golden[1])
    assert srv.report()["per_chip"][1]["seu_disagreements"][2] > 0
    # dispatch 2: the scrub step AFTER the counters folded is steered
    # straight to the hot frame — detected and healed within ONE interval
    np.testing.assert_array_equal(_serve(srv, X, 1), golden[1])
    rep = srv.report()["scrub"]
    assert rep["detections"] == 1 and rep["healed_bits"] == 1, rep
    assert rep["steps"] - steps_before <= 2
    assert rep["detection_latency_dispatches"]["max"] >= 1
    assert all(srv.verify_frame(1, r) for r in range(3))
    # healed: counters stop climbing on a fresh batch
    base = srv.report()["per_chip"][1]["seu_disagreements"][2]
    np.testing.assert_array_equal(_serve(srv, X, 1), golden[1])
    assert srv.report()["per_chip"][1]["seu_disagreements"][2] == base


def test_scrub_acceptance_kernel_banded_and_dense(duo):
    """Acceptance matrix: an SEU injected during a live kernel stream is
    CRC-detected and healed by the background scrubber, banded AND dense,
    with zero wrong outputs under single-fault TMR conditions."""
    chips, X = duo
    chip = chips[0]
    Xs = X[:32]
    golden = _golden(chip, Xs)
    for band in (None, False):
        srv = ReadoutServer([chip], ServerConfig(
            max_batch=len(Xs), max_latency_s=1e9, backend="kernel",
            redundancy="tmr", band=band, scrub_interval=1,
            pipeline_depth=1))
        srv.inject_seu(0, 1, 3, 7)
        assert not srv.verify_frame(0, 1), f"band={band}"
        # 3 frames, interval 1: healed within one full scrub cycle of
        # the stream even if steering never fires (the flip may not be
        # output-effective) — kernel readbacks verify one step after
        # they are issued; every served batch stays golden throughout
        for _ in range(5):
            np.testing.assert_array_equal(
                _serve(srv, Xs), golden, err_msg=f"band={band}")
            if srv.report()["scrub"]["detections"]:
                break
        rep = srv.report()["scrub"]
        assert rep["detections"] == 1 and rep["healed_bits"] == 1, (band, rep)
        assert all(srv.verify_frame(0, r) for r in range(3)), band
        np.testing.assert_array_equal(_serve(srv, Xs), golden)


def test_scrub_crc_only_without_redundancy(duo):
    """No TMR, no vote: the CRC readback is the ONLY detection. The
    unprotected chip serves wrong scores while the fault is live — and
    the scrubber still finds and repairs it, bounding the exposure window
    to one scrub interval (x frames)."""
    chips, X = duo
    chip = chips[0]
    Xs = X[:32]
    golden = _golden(chip, Xs)
    li, bi = _effective_flip(chip, Xs)
    for backend in ("host", "kernel"):
        srv = ReadoutServer([chip], ServerConfig(
            max_batch=len(Xs), max_latency_s=1e9, backend=backend,
            redundancy="none", scrub_interval=1, pipeline_depth=1))
        assert srv.n_replicas == 1
        srv.inject_seu(0, 0, li, bi)
        assert not srv.verify_frame(0, 0), backend
        wrong = _serve(srv, Xs)     # fault live: outputs corrupted
        assert not np.array_equal(wrong, golden), backend
        # ... and the scrubber finds and repairs it within a couple of
        # dispatches (host verifies in place; kernel readbacks verify
        # one scrub step after they are issued), bounding the exposure
        for _ in range(3):
            if srv.report()["scrub"]["detections"]:
                break
            _serve(srv, Xs)
        rep = srv.report()["scrub"]
        assert rep["detections"] == 1 and rep["healed_bits"] == 1, (
            backend, rep)
        np.testing.assert_array_equal(_serve(srv, Xs), golden,
                                      err_msg=backend)


def test_scrub_heals_fused_frames_path(duo):
    """Heal refreshes the fused frontend's shared stack too: a frames
    stream through the kernel backend scores golden again after the
    scrubber repairs an injected upset."""
    chips, _ = duo
    chip = chips[0]
    d = generate(SmartPixelConfig(n_events=32, seed=77), return_frames=True)
    frames, y0 = d["frames"], d["features"][:, 13]
    srv = ReadoutServer([chip], ServerConfig(
        max_batch=len(frames), max_latency_s=1e9, backend="kernel",
        redundancy="tmr", scrub_interval=1, pipeline_depth=1))

    def stream_scores():
        srv.submit_frames(0, frames, y0)
        res = sorted(srv.flush(), key=lambda r: r.seq)
        return np.array([r.score_raw for r in res])

    want = stream_scores()          # golden reference (healthy server)
    srv.inject_seu(0, 2, 1, 9)
    for _ in range(6):
        np.testing.assert_array_equal(stream_scores(), want)
        if srv.report()["scrub"]["detections"]:
            break
    assert srv.report()["scrub"]["detections"] == 1
    assert all(srv.verify_frame(0, r) for r in range(3))
    np.testing.assert_array_equal(stream_scores(), want)


def test_reconfigure_refreshes_golden_store(duo):
    """After a hot-swap the slot's golden truth IS the new bitstream: a
    full scrub cycle reports zero detections (no false positives against
    the stale golden), and a subsequent upset heals to the NEW config."""
    chips, X = duo
    a, b = chips
    srv = ReadoutServer([a], ServerConfig(
        max_batch=len(X), max_latency_s=1e9, backend="host",
        redundancy="tmr", scrub_interval=1, pipeline_depth=1))
    np.testing.assert_array_equal(_serve(srv, X), _golden(a, X))
    srv.reconfigure(0, b)
    assert not srv.scrub_cycle(), "stale golden after reconfigure"
    assert srv.report()["scrub"]["detections"] == 0
    srv.inject_seu(0, 1, 0, 4)
    healed = srv.scrub_cycle()
    assert len(healed) == 1 and healed[0]["healed_bits"] == 1
    np.testing.assert_array_equal(_serve(srv, X), _golden(b, X))


# ------------------------------------------------------ committed bench
def test_bench_json_has_scrub_scenario():
    """The committed benchmark record must carry the scrubbing scenario:
    the overhead ratio the CI regression gate tracks and the Poisson
    mean-time-to-heal record."""
    path = os.path.join(os.path.dirname(__file__), "..", "BENCH_fabric.json")
    with open(path) as f:
        doc = json.load(f)
    names = {r["name"] for r in doc["records"]}
    assert any(n.startswith("fabric.scrub_on_") for n in names), names
    assert any(n.startswith("fabric.scrub_off_") for n in names), names
    rows = {r["name"]: r for r in doc["records"]}
    ov = rows["fabric.scrub_overhead"]
    assert 0.0 < ov["events_per_s_ratio"] <= 1.5
    # The bit-sliced frontend serves the same stream ~200x faster, so the
    # unchanged absolute readback/CRC cost per scrub step is now a much
    # larger *fraction* of stream time than the <5% the original interval
    # was budgeted for.  The scrub_relax degrade-ladder rung amortizes it
    # under deadline pressure; here we bound the steady-state fraction.
    assert ov["overhead_frac"] < 0.5, (
        "scrub overhead at the default interval must stay under 50% of the "
        "bit-sliced stream time")
    mtth = rows["fabric.scrub_mtth"]
    assert mtth["faults_healed"] >= 1
    assert mtth["mean_batches_to_heal"] > 0


# ------------------------------------------------- the regression gate
def _load_gate():
    path = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                        "check_regression.py")
    spec = importlib.util.spec_from_file_location("check_regression", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _gate_doc(scale=1.0, smoke=False):
    recs = [
        {"name": "fabric.frames_fused_speedup", "speedup": 1.1 * scale},
        {"name": "fabric.tmr_sparse_link_bytes", "wire_reduction": 2.3 * scale},
        {"name": "fabric.deep_ensemble4_banded_tree_speedup",
         "speedup": 7.0 * scale},
        {"name": "fabric.deep_ensemble4_bitsliced_speedup",
         "speedup": 10_000.0 * scale},
        # lower-is-better: scale < 1 must push it UP (a regression)
        {"name": "fabric.deep_ensemble4_sparse_egress",
         "bytes_ratio": 0.36 / scale},
        {"name": "fabric.scrub_overhead", "events_per_s_ratio": 0.97 * scale},
        {"name": "fabric.scrub_mtth", "mean_batches_to_heal": 2.0},
        {"name": "fabric.bitsliced_speedup", "speedup": 1000.0 * scale},
        {"name": "fabric.bitsliced_tmr_overhead",
         "tmr_overhead": 0.9, "efficiency": 1.1 * scale},
        {"name": "fabric.multichip_1x64ev", "chips": 1,
         "events_per_s": 1000.0},
        {"name": "fabric.multichip_2x64ev", "chips": 2,
         "events_per_s": 1100.0},
        {"name": "fabric.latency_p99", "p99_us": 30000.0},
        # lower-is-better: scale < 1 must push it UP (a regression)
        {"name": "fabric.deadline_p99", "p99_frac_of_deadline": 0.6 / scale},
        {"name": "fabric.overload_shed_accounting", "coverage": 1.0 * scale},
        {"name": "net.loopback_replay", "frac_of_inprocess": 0.9 * scale},
        # lower-is-better: scale < 1 must push it UP (a regression)
        {"name": "net.e2e_latency", "p99_frac": 15.0 / scale},
        {"name": "fleet.admission_warm", "warm_over_cold": 12.5 * scale},
    ]
    return {"benchmark": "fabric", "smoke": smoke, "records": recs}


def test_check_regression_gate(tmp_path):
    gate = _load_gate()
    fresh, base = tmp_path / "fresh.json", tmp_path / "base.json"
    base.write_text(json.dumps(_gate_doc()))

    # smoke tier passes on structure alone, even with degraded numbers
    fresh.write_text(json.dumps(_gate_doc(scale=0.5, smoke=True)))
    argv = ["--fresh", str(fresh), "--baseline", str(base)]
    assert gate.main(argv + ["--tier", "smoke"]) == 0

    # nightly: within-threshold drop passes, >25% drop fails
    fresh.write_text(json.dumps(_gate_doc(scale=0.9)))
    assert gate.main(argv + ["--tier", "nightly"]) == 0
    fresh.write_text(json.dumps(_gate_doc(scale=0.5)))
    assert gate.main(argv + ["--tier", "nightly"]) == 1

    # nightly refuses smoke-generated numbers — fresh OR baseline side
    fresh.write_text(json.dumps(_gate_doc(smoke=True)))
    with pytest.raises(SystemExit, match="SMOKE"):
        gate.main(argv + ["--tier", "nightly"])
    fresh.write_text(json.dumps(_gate_doc()))
    base.write_text(json.dumps(_gate_doc(smoke=True)))
    with pytest.raises(SystemExit, match="baseline"):
        gate.main(argv + ["--tier", "nightly"])
    base.write_text(json.dumps(_gate_doc()))

    # a missing tracked record is a structural failure in EITHER tier
    doc = _gate_doc()
    doc["records"] = [r for r in doc["records"]
                      if not r["name"].startswith("fabric.scrub_")]
    fresh.write_text(json.dumps(doc))
    with pytest.raises(SystemExit, match="scrub"):
        gate.main(argv + ["--tier", "smoke"])

    # multichip events/s decreasing with chip count is structural too
    doc = _gate_doc()
    for r in doc["records"]:
        if r["name"] == "fabric.multichip_2x64ev":
            r["events_per_s"] = 600.0  # < 0.75 * the 1-chip 1000.0
    fresh.write_text(json.dumps(doc))
    with pytest.raises(SystemExit, match="multichip"):
        gate.main(argv + ["--tier", "smoke"])

    # lower-is-better direction: a >25% RISE in the admitted-overload
    # p99/deadline fraction fails nightly on its own
    doc = _gate_doc()
    for r in doc["records"]:
        if r["name"] == "fabric.deadline_p99":
            r["p99_frac_of_deadline"] = 0.9   # baseline 0.6 -> +50%
    fresh.write_text(json.dumps(doc))
    assert gate.main(argv + ["--tier", "nightly"]) == 1

    # per-key drift slack: net_e2e_p99_frac carries a 2x band, so a
    # +33% rise (> the default 25%) still passes, while +120% fails
    doc = _gate_doc()
    for r in doc["records"]:
        if r["name"] == "net.e2e_latency":
            r["p99_frac"] = 20.0    # baseline 15.0 -> +33%
    fresh.write_text(json.dumps(doc))
    assert gate.main(argv + ["--tier", "nightly"]) == 0
    for r in doc["records"]:
        if r["name"] == "net.e2e_latency":
            r["p99_frac"] = 33.0    # +120% > the 2x-slack 50% band
    fresh.write_text(json.dumps(doc))
    assert gate.main(argv + ["--tier", "nightly"]) == 1
