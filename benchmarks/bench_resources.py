"""§2.1/§4.1/§5 resource table: fabric capacities, BDT fit, NN non-fit."""
from __future__ import annotations

import time

from repro.core.bdt import GradientBoostedClassifier
from repro.core.fabric import FABRIC_130NM, FABRIC_28NM, place_and_route
from repro.core.nn_baseline import MLPSpec, lut_cost
from repro.core.synth import synth_ensemble
from repro.data.smartpixel import SmartPixelConfig, generate, train_test_split


def run(emit):
    for spec in (FABRIC_130NM, FABRIC_28NM):
        t = spec.totals()
        emit(f"resources.fabric_{spec.node}", 0.0,
             f"logic_cells={t['logic_cells']};dsp={t['dsp_slices']};"
             f"lutram_bits={t['lutram_bits']};io_in={spec.input_capacity}")

    data = generate(SmartPixelConfig(n_events=60_000, seed=2024))
    tr, _ = train_test_split(data)
    clf = GradientBoostedClassifier(
        n_estimators=1, max_depth=5, max_leaf_nodes=10, min_samples_leaf=500
    ).fit(tr["features"], tr["label"])
    t0 = time.perf_counter()
    synth = synth_ensemble(clf.quantized())
    synth_us = (time.perf_counter() - t0) * 1e6
    cfgf = place_and_route(synth.netlist, FABRIC_28NM)
    u = cfgf.utilization()
    emit("resources.bdt_synthesis", synth_us,
         f"luts={synth.report['luts']};depth={synth.report['depth']};"
         f"thresholds={synth.n_thresholds};paper_luts=294;capacity=448;"
         f"utilization={u['lut_utilization']:.2f}")

    nn = lut_cost(MLPSpec())
    emit("resources.nn_baseline_luts", 0.0,
         f"lut_total={nn['lut_total']};paper=>6000;fits_448={nn['lut_total'] <= 448}")

    # TMR (paper §5 future work): 3x replicas + voters
    from repro.core.tmr import FABRIC_28NM_XL, triplicate

    tmr = triplicate(synth.netlist)
    emit("resources.bdt_tmr", 0.0,
         f"luts={tmr.resource_report()['luts']};fits_448={tmr.n_luts <= 448};"
         f"fits_next_gen_{FABRIC_28NM_XL.n_logic_cells}={tmr.n_luts <= FABRIC_28NM_XL.n_logic_cells}")

    # ensemble scaling: biggest ensemble that still fits 448 LUTs, under
    # both summation structures (tree = default, fast/deep-friendly;
    # ripple = minimal area — the speed/area trade is the point here)
    for n_est, depth in [(1, 5), (2, 4), (3, 3)]:
        c = GradientBoostedClassifier(
            n_estimators=n_est, max_depth=depth, max_leaf_nodes=8
        ).fit(tr["features"], tr["label"])
        parts = []
        for adder in ("tree", "ripple"):
            s = synth_ensemble(c.quantized(), adder=adder)
            parts.append(
                f"luts_{adder}={s.report['luts']};"
                f"depth_{adder}={s.report['depth']};"
                f"fits_28nm_{adder}={str(s.report['luts'] <= 448).lower()}"
            )
        emit(f"resources.ensemble_{n_est}x{depth}", 0.0, ";".join(parts))
