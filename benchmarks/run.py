# One function per paper table. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark harness: one module per paper table/figure + the roofline
report derived from the dry-run artifacts.

    PYTHONPATH=src python -m benchmarks.run            # all
    PYTHONPATH=src python -m benchmarks.run bdt power  # subset
    REPRO_BENCH_FULL=1 ...                             # 500k events (paper scale)
"""
from __future__ import annotations

import sys
import traceback

from benchmarks import (
    bench_bdt, bench_fabric, bench_latency, bench_power, bench_resources,
    roofline,
)

MODULES = {
    "bdt": bench_bdt,              # Table 1 + §5 float numbers
    "power": bench_power,          # Fig. 5 / Fig. 10 + §3 factors
    "resources": bench_resources,  # §2.1/§4.1/§5 resource table
    "latency": bench_latency,      # §5 <25 ns
    "fabric": bench_fabric,        # counter/loopback/classifier throughput
    "roofline": roofline,          # framework perf report (§Roofline)
}


def main() -> None:
    names = sys.argv[1:] or list(MODULES)
    print("name,us_per_call,derived")

    def emit(name: str, us: float, derived: str = ""):
        print(f"{name},{us:.2f},{derived}", flush=True)

    failed = []
    for n in names:
        try:
            MODULES[n].run(emit)
        except Exception:
            failed.append(n)
            traceback.print_exc()
    if failed:
        raise SystemExit(f"benchmark modules failed: {failed}")


if __name__ == "__main__":
    main()
