# One function per paper table. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark harness: one module per paper table/figure + the roofline
report derived from the dry-run artifacts.

    PYTHONPATH=src python -m benchmarks.run            # all
    PYTHONPATH=src python -m benchmarks.run bdt power  # subset
    PYTHONPATH=src python -m benchmarks.run fabric --profile=trace_dir
    REPRO_BENCH_FULL=1 ...                             # 500k events (paper scale)
"""
from __future__ import annotations

import os
import sys
import traceback

from benchmarks import (
    bench_bdt, bench_fabric, bench_latency, bench_net, bench_power,
    bench_resources, layout_matrix, roofline,
)

MODULES = {
    "bdt": bench_bdt,              # Table 1 + §5 float numbers
    "power": bench_power,          # Fig. 5 / Fig. 10 + §3 factors
    "resources": bench_resources,  # §2.1/§4.1/§5 resource table
    "latency": bench_latency,      # §5 <25 ns
    "fabric": bench_fabric,        # counter/loopback/classifier throughput
    "net": bench_net,              # wire protocol + loopback replay toll
    "layout_matrix": layout_matrix,  # layout x band x redundancy sweep
    "roofline": roofline,          # framework perf report (§Roofline)
}


def main() -> None:
    names = []
    for arg in sys.argv[1:]:
        # --profile[=DIR]: jax.profiler trace of the fabric suite
        if arg == "--profile" or arg.startswith("--profile="):
            _, _, trace_dir = arg.partition("=")
            os.environ["REPRO_BENCH_PROFILE"] = trace_dir or "bench_trace"
            bench_fabric._PROFILE_DIR = os.environ["REPRO_BENCH_PROFILE"]
            continue
        names.append(arg)
    names = names or list(MODULES)
    print("name,us_per_call,derived")

    def emit(name: str, us: float, derived: str = ""):
        print(f"{name},{us:.2f},{derived}", flush=True)

    failed = []
    for n in names:
        try:
            MODULES[n].run(emit)
        except Exception:
            failed.append(n)
            traceback.print_exc()
    if failed:
        raise SystemExit(f"benchmark modules failed: {failed}")


if __name__ == "__main__":
    main()
