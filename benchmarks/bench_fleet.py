"""Multi-tenant fleet scenario: admission latency, evict/re-admit cost,
and serving throughput vs tenant count (launch/fleet.py).

Run as part of the fabric suite (bench_fabric.py calls
``bench_fleet_scenario``); the records land in BENCH_fabric.json under
the ``fleet.*`` prefix. Every key is documented in docs/benchmarks.md.

The headline, machine-independent gate metric is
``fleet.admission_warm .warm_over_cold``: how much cheaper admitting a
tenant into a WARM geometry bucket (pure array swap through
``reconfigure``) is than the COLD first admission (bucket server build
+ first-dispatch jit compile). A drop means warm admission started
paying compile-path work again — exactly the regression the bucketed
envelopes exist to prevent; the bench also hard-asserts zero retraces
on the warm path when jit cache introspection is available.

Set REPRO_FLEET_JSON=<path> to additionally dump just the ``fleet.*``
records as a standalone JSON (the nightly FLEET-scaling artifact).
"""
from __future__ import annotations

import json
import os
import time

import numpy as np


def bench_fleet_scenario(note, chip_pool, te, smoke):
    from repro.kernels.lut_eval import ops as lut_ops
    from repro.launch.fleet import TenantFleet
    from repro.launch.readout_server import ServerConfig

    X = te["features"]
    cfg = ServerConfig(max_batch=512, max_latency_s=1e9, backend="kernel",
                       batch_tile=128)

    def mk():
        return TenantFleet(cfg, bucket_slots=4)

    envs = [lut_ops.bucket_envelope(c.config) for c in chip_pool]
    # a same-envelope pair for the warm-admission measurement (fall back
    # to the same design twice: still a distinct tenant admission)
    pair = next(((i, j) for i in range(len(envs))
                 for j in range(i + 1, len(envs)) if envs[i] == envs[j]),
                (0, 0))

    # --- admission latency: cold (bucket build + compile) vs warm (swap)
    fleet = mk()
    can_count = hasattr(lut_ops._eval_stack_scored, "_cache_size")

    def admit_and_serve(tenant, chip):
        t0 = time.perf_counter()
        fleet.admit(tenant, chip)
        fleet.submit(tenant, X[0])
        fleet.flush()
        return time.perf_counter() - t0

    t_cold = admit_and_serve("t_cold", chip_pool[pair[0]])
    n0 = lut_ops._eval_stack_scored._cache_size() if can_count else -1
    t_warm = admit_and_serve("t_warm", chip_pool[pair[1]])
    retraces = ((lut_ops._eval_stack_scored._cache_size() - n0)
                if can_count else 0)
    assert retraces == 0, (
        f"warm admission must not retrace, got {retraces} new jit entries")
    note("fleet.admission_cold", t_cold * 1e6,
         f"includes_compile=true;bucket_slots=4")
    note("fleet.admission_warm", t_warm * 1e6,
         f"warm_over_cold={t_cold / t_warm:.1f};retraces={retraces};"
         f"same_envelope=true")

    # --- evict / re-admit-from-golden cost, bit-exact after the round trip
    chip = chip_pool[pair[1]]
    t0 = time.perf_counter()
    fleet.evict("t_warm")
    t_evict = time.perf_counter() - t0
    row = X[1]
    t0 = time.perf_counter()
    s = fleet.submit("t_warm", row)          # transparent golden re-admit
    (r,) = [e for e in fleet.flush() if e.seq == s]
    t_readmit = time.perf_counter() - t0
    want = int(chip.infer_raw(row[None], backend="host")[0])
    assert r.score_raw == want, "re-admitted tenant diverged from oracle"
    note("fleet.evict_readmit", (t_evict + t_readmit) * 1e6,
         f"evict_us={t_evict * 1e6:.0f};readmit_us={t_readmit * 1e6:.0f};"
         f"bit_exact_vs_golden=true")

    # --- events/s vs tenant count: every tenant cycles through the pool's
    # envelopes; counts past bucket capacity churn the LRU evict/re-admit
    # path, so the large points price elasticity, not just the kernel
    B = 8 if smoke else 16
    tenant_counts = (2, 8) if smoke else (2, 16, 64)
    for n_tenants in tenant_counts:
        fl = mk()
        for i in range(n_tenants):
            fl.admit(f"t{i}", chip_pool[i % len(chip_pool)])
        t0 = time.perf_counter()
        got = 0
        for i in range(n_tenants):
            seqs = fl.submit_batch(f"t{i}", X[:B])
            got += sum(s is not None for s in seqs)
        done = fl.flush()
        t = time.perf_counter() - t0
        rep = fl.report()
        assert len(done) == got, "fleet dropped admitted events"
        assert rep["events_in"] == rep["events_out"], rep
        ev = n_tenants * B
        note(f"fleet.serve_{n_tenants}tenants", t * 1e6,
             f"events_per_s={ev / t:.0f};tenants={n_tenants};"
             f"buckets={rep['n_buckets']};bucket_slots=4;"
             f"events_per_tenant={B};"
             f"readmissions={sum(v['readmissions'] for v in rep['tenants'].values())}")

    path = os.environ.get("REPRO_FLEET_JSON", "")
    if path:
        rows = [r for r in getattr(note, "records", [])
                if str(r.get("name", "")).startswith("fleet.")]
        with open(path, "w") as f:
            json.dump({"benchmark": "fleet", "smoke": smoke,
                       "records": rows}, f, indent=2, sort_keys=True)
            f.write("\n")
