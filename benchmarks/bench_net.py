# Network front door: loopback replay throughput vs in-process serving.
"""How much of the serving loop's event rate survives the wire?

The front door (``net/ingress.py``) puts a versioned binary protocol,
an asyncio socket hop, per-client sequence accounting and the sparse
trigger egress between the sensor and ``submit_frames``. This module
measures that toll on loopback, where the network itself is free — so
the ``net.*`` records isolate the protocol + event-loop overhead:

* ``net.inprocess_baseline`` — dense ``submit_frames`` in an unpaced
  tight loop on the kernel backend: the in-process BURST ceiling.
* ``net.loopback_ceiling`` — the same events flooded through TCP
  loopback as fast as the closed loop allows. Its
  ``frac_of_inprocess_burst`` is deliberately NOT gated: an equal-work
  single-process comparison is bounded by per-byte costs that have
  nothing to do with the front door's design — at 8.7 KB/event the
  payload CRC32 alone is ~8 us/event at this container's ~1 GB/s zlib,
  plus ~5 us of buffer copies and ~4 us of socket recv, against a
  ~30 us/event service. The record documents that toll honestly
  (measured ~0.5-0.6) so a future fast-CRC or zero-copy ingest PR has
  a number to move.
* ``net.loopback_replay`` — THE acceptance leg: replay PACED at the
  bench rate (half the burst ceiling — the 2x provisioning headroom
  the deadline suite's square-wave calibration targets) vs an
  in-process driver paced at the same rate. ``frac_of_inprocess`` is
  achieved-over-the-wire / achieved-in-process at that operating
  point; the full run asserts >= 0.8 (the PR's acceptance floor: the
  front door must not throttle serving at the system's operating
  point). Closed-loop: every trigger is verified bit-exact against
  the host oracle before the record is written.
* ``net.e2e_latency`` — a latency-tuned serving point: 5 ms coalesce
  window, paced at 0.15x the burst ceiling (utilization low
  enough that the number measures service + wire, not queue depth).
  Reports the MEDIAN over 5 seeded runs of p50/p99 submit->trigger
  wall time per event — single-run tail percentiles swing >30% under
  host scheduling noise, medians hold still. ``p99_frac`` = p99 in
  units of the ideal batch service time (machine-speed independent,
  the second tracked number — it rises when the front door starts
  queuing).
* ``net.wire_bytes`` — bytes per event in both directions (the frame
  ingest is the dominant term: 20 B header + 4 B y0 + 8*13*21 f32).

Standalone: ``PYTHONPATH=src python -m benchmarks.run net``. Also runs
as the tail of the fabric suite so the records land in BENCH_fabric.json
for ``check_regression.py``.
"""
from __future__ import annotations

import asyncio
import time

import numpy as np

from repro.launch.readout_server import ReadoutServer, ServerConfig


def _mk_server(chips):
    # the real serving backend (kernel), dense ingest as the front door
    # requires; max_latency bounded so the coalescer launches on its own
    # under a paced stream, huge relative to service time so the unpaced
    # runs still form full batches
    return ReadoutServer(chips, ServerConfig(
        max_batch=256, max_latency_s=50e-3, backend="kernel",
        batch_tile=128))


def _mk_latency_server(chips, source):
    """A 5 ms-window server for the latency leg, with every pow2 pad
    bucket pre-compiled: a paced stream dispatches partial coalesce
    groups whose padded shapes would otherwise pay a first jit compile
    mid-measurement (the bench_latency warmup pattern)."""
    srv = ReadoutServer(chips, ServerConfig(
        max_batch=256, max_latency_s=5e-3, backend="kernel",
        batch_tile=128))
    fr, z = source(0)
    k = 256
    while k >= 1:
        srv.submit_frames(0, fr[:min(k, len(fr))], z[:min(k, len(z))])
        srv.flush()
        k //= 2
    return srv


def _warm(chips, source, n_batches):
    """Warm the jit cache on a throwaway server with the same batch
    shapes every run below uses: the first dispatch of each padded
    shape pays a one-time compile (hundreds of ms) that must not count
    against any measured number."""
    warm = _mk_server(chips)
    for b in range(n_batches):
        fr, z = source(b)
        warm.submit_frames(0, fr, z)
        warm.poll()
    warm.flush()
    # the tail flush can leave a partial batch -> a second padded shape
    fr, z = source(0)
    warm.submit_frames(0, fr, z)
    warm.flush()


def _inprocess_burst(chips, source, n_batches):
    """Unpaced dense submit_frames in a tight loop: the burst ceiling."""
    srv = _mk_server(chips)
    n_events = 0
    res = []
    t0 = time.perf_counter()
    for b in range(n_batches):
        fr, z = source(b)
        srv.submit_frames(0, fr, z)
        n_events += len(fr)
        res.extend(srv.poll())
    res.extend(srv.flush())
    dt = time.perf_counter() - t0
    assert len(res) == n_events, (len(res), n_events)
    kept = sum(1 for r in res if r.keep)
    return n_events / dt, dt, n_events, kept


def _inprocess_paced(chips, source, n_batches, rate_ev_s):
    """Dense submit_frames driven open-loop at ``rate_ev_s``: batch b
    is submitted when its scheduled arrival passes, polls run between
    arrivals (the run_open_loop driver structure from bench_latency).
    Returns the achieved closed-loop events/s at that operating point."""
    srv = _mk_server(chips)
    per = len(source(0)[0])
    n_events = 0
    res = []
    clock = time.perf_counter
    t0 = clock()
    b = 0
    while b < n_batches:
        if b * per / rate_ev_s <= clock() - t0:
            fr, z = source(b)
            srv.submit_frames(0, fr, z)
            n_events += len(fr)
            b += 1
        res.extend(srv.poll())
    res.extend(srv.flush())
    dt = clock() - t0
    assert len(res) == n_events, (len(res), n_events)
    return n_events / dt


def _replay_once(chips, source, oracle, cfg, mk_srv=None):
    from repro.net.ingress import ReadoutFrontDoor
    from repro.net.replay import replay

    srv = mk_srv() if mk_srv is not None else _mk_server(chips)
    door = ReadoutFrontDoor(srv)

    async def go():
        await door.start()
        try:
            return await replay("127.0.0.1", door.tcp_port, source, cfg,
                                oracle)
        finally:
            await door.stop()

    return asyncio.run(go())


def bench_net_scenario(note, chips, frames, y0, smoke: bool):
    """The net suite (called from bench_fabric's run and standalone).
    ``chips`` — the front door serves chips[:1]; ``frames``/``y0`` — the
    recorded event pool the source wraps around."""
    from repro.net.replay import ReplayConfig, array_source, host_oracle

    chips = chips[:1]
    n_batches, per = (6, 16) if smoke else (48, 64)
    source = array_source(np.asarray(frames, np.float32),
                          np.asarray(y0, np.float32), per)
    oracle = host_oracle(chips[0])

    _warm(chips, source, n_batches)
    # median of 3: the burst ceiling anchors every rate below, and a
    # single tight-loop timing wobbles ~10% under host contention
    trials = [_inprocess_burst(chips, source, n_batches)
              for _ in range(1 if smoke else 3)]
    base_ev_s = float(np.median([t[0] for t in trials]))
    base_dt, n_events, base_kept = trials[0][1], trials[0][2], trials[0][3]
    note("net.inprocess_baseline", n_events / base_ev_s * 1e6,
         f"events_per_s={base_ev_s:.0f};events={n_events};"
         f"kept={base_kept};backend=kernel;dense=true;driver=burst;"
         f"runs={len(trials)}")

    # --- unpaced loopback flood: the wire path's own ceiling. The
    # frac vs the in-process burst is reported, not gated: it is
    # dominated by per-byte CRC32 + copy costs (see module docstring).
    cfg = ReplayConfig(rate_hz=0.0, n_batches=n_batches,
                       events_per_batch=per, transport="tcp",
                       pre_encode=True)
    rep = _replay_once(chips, source, oracle, cfg)
    assert rep.verified, rep.mismatches[:3]
    assert rep.ack["events_in"] == n_events == rep.ack["events_admitted"]
    assert rep.n_kept == base_kept, (rep.n_kept, base_kept)
    ceil_ev_s = rep.achieved_ev_s
    note("net.loopback_ceiling", n_events / ceil_ev_s * 1e6,
         f"events_per_s={ceil_ev_s:.0f};"
         f"frac_of_inprocess_burst={ceil_ev_s / base_ev_s:.3f};"
         f"events={n_events};kept={rep.n_kept};transport=tcp;"
         f"verified=true;pre_encode=true")
    note("net.wire_bytes", 0.0,
         f"bytes_per_event={rep.wire_bytes_per_event:.1f};"
         f"bytes_out={rep.bytes_out};bytes_in={rep.bytes_in};"
         f"events={n_events}")

    # --- the acceptance leg: paced at the bench rate (half the burst
    # ceiling = the 2x provisioning headroom the deadline suite's
    # square-wave calibration targets), wire vs in-process at the SAME
    # operating point. The front door passes when it does not throttle
    # serving at that rate.
    bench_rate = 0.5 * base_ev_s
    paced_base_ev_s = _inprocess_paced(chips, source, n_batches,
                                       bench_rate)
    cfg = ReplayConfig(rate_hz=bench_rate, pattern="poisson",
                       n_batches=n_batches, events_per_batch=per,
                       transport="tcp", seed=3)
    rep = _replay_once(chips, source, oracle, cfg)
    assert rep.verified, rep.mismatches[:3]
    assert rep.ack["events_in"] == n_events == rep.ack["events_admitted"]
    frac = rep.achieved_ev_s / paced_base_ev_s
    note("net.loopback_replay", n_events / rep.achieved_ev_s * 1e6,
         f"events_per_s={rep.achieved_ev_s:.0f};"
         f"frac_of_inprocess={frac:.3f};"
         f"bench_rate_ev_s={bench_rate:.0f};"
         f"inprocess_paced_ev_s={paced_base_ev_s:.0f};"
         f"events={n_events};kept={rep.n_kept};transport=tcp;"
         f"verified=true;arrival=poisson_0.5x_burst")
    if not smoke:
        # the PR's acceptance floor: at the bench rate the wire path
        # keeps >= 80% of the in-process event rate
        assert frac >= 0.8, (
            f"loopback replay at the bench rate sustained only "
            f"{frac:.1%} of the in-process rate ({rep.achieved_ev_s:.0f}"
            f" vs {paced_base_ev_s:.0f} ev/s at {bench_rate:.0f} ev/s)")

    # --- e2e latency at a latency-tuned serving point: 5 ms window,
    # 0.15x the burst ceiling, median of 3 seeded runs (single-run
    # tail percentiles swing >30% under host scheduling noise)
    lat_rate = 0.15 * base_ev_s
    cfg = ReplayConfig(rate_hz=lat_rate, pattern="poisson",
                       n_batches=n_batches, events_per_batch=per,
                       transport="tcp", seed=3)
    runs = []
    for _ in range(1 if smoke else 5):
        rep = _replay_once(chips, source, oracle, cfg,
                           mk_srv=lambda: _mk_latency_server(
                               chips, source))
        assert rep.verified, rep.mismatches[:3]
        runs.append(rep)
    p50 = float(np.median([r.latency["p50_us"] for r in runs]))
    p99 = float(np.median([r.latency["p99_us"] for r in runs]))
    ach = float(np.median([r.achieved_ev_s for r in runs]))
    ideal_batch_us = per / base_ev_s * 1e6
    p99_frac = p99 / ideal_batch_us
    note("net.e2e_latency", p99,
         f"p50_us={p50:.1f};p99_us={p99:.1f};"
         f"p99_frac={p99_frac:.3f};"
         f"rate_ev_s={lat_rate:.0f};"
         f"achieved_ev_s={ach:.0f};"
         f"ideal_batch_us={ideal_batch_us:.1f};"
         f"runs={len(runs)};window_ms=5;arrival=poisson_0.15x")


def run(emit):
    """Standalone leg: builds its own chip + frame pool, then runs the
    same scenario bench_fabric embeds."""
    from benchmarks.bench_fabric import _Recorder, _SMOKE
    from repro.core.bdt import GradientBoostedClassifier
    from repro.core.readout import ReadoutChip
    from repro.data.smartpixel import (
        SmartPixelConfig, generate, train_test_split)

    note = _Recorder(emit)
    n_fr = 512 if _SMOKE else 2_048
    d = generate(SmartPixelConfig(n_events=8_000, seed=5))
    tr, _ = train_test_split(d)
    clf = GradientBoostedClassifier(
        n_estimators=1, max_depth=5, max_leaf_nodes=10,
        min_samples_leaf=500,
    ).fit(tr["features"], tr["label"])
    chip = ReadoutChip.build(clf)
    chip.calibrate(tr["features"], tr["label"], target_sig_eff=0.95)
    d2 = generate(SmartPixelConfig(n_events=n_fr, seed=7),
                  return_frames=True)
    bench_net_scenario(note, [chip], d2["frames"], d2["features"][:, 13],
                       smoke=_SMOKE)
