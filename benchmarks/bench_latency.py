"""§5 latency claim: "operational runtime of less than 25 ns in simulation".

On silicon the BDT decision function is one combinational pass; its latency
is (logic depth) x (per-LUT+routing delay). We report the synthesized
netlist's combinational depth and the implied latency at the 28nm ASIC's
200 MHz P&R constraint (5 ns clock => depth/levels-per-cycle pipeline view)
plus a per-LUT delay model (~1.0 ns/level at 28nm incl. routing, matching
the paper's <25 ns for a ~12-20 level module).
"""
from __future__ import annotations

from repro.core.bdt import GradientBoostedClassifier
from repro.core.synth import synth_ensemble
from repro.data.smartpixel import SmartPixelConfig, generate, train_test_split

NS_PER_LEVEL_28NM = 1.0   # LUT4 + local routing at 28nm (conservative)
NS_PER_LEVEL_130NM = 2.6


def run(emit):
    data = generate(SmartPixelConfig(n_events=50_000, seed=2024))
    tr, _ = train_test_split(data)
    clf = GradientBoostedClassifier(
        n_estimators=1, max_depth=5, max_leaf_nodes=10, min_samples_leaf=500
    ).fit(tr["features"], tr["label"])
    synth = synth_ensemble(clf.quantized())
    depth = synth.report["depth"]
    lat28 = depth * NS_PER_LEVEL_28NM
    emit("latency.bdt_28nm", 0.0,
         f"levels={depth};ns={lat28:.1f};paper=<25ns;meets={lat28 < 25}")
    emit("latency.bdt_130nm", 0.0,
         f"levels={depth};ns={depth * NS_PER_LEVEL_130NM:.1f}")
    # one fabric evaluation per 40 MHz bunch crossing needs < 25 ns:
    emit("latency.bunch_crossing_budget", 0.0,
         f"budget_ns=25;at_40MHz_period_ns=25;single_pass={lat28 < 25}")

    # the NN alternative on the 4 DSP slices (time-multiplexed): fails the
    # latency budget even if the LUT problem were solved
    from repro.core.nn_baseline import MLPSpec, dsp_schedule

    d = dsp_schedule(MLPSpec())
    emit("latency.nn_dsp_schedule", 0.0,
         f"macs={int(d['macs'])};cycles={int(d['cycles'])};"
         f"ns={d['latency_ns']:.0f};meets_25ns={d['meets_25ns']}")
