"""Latency: the paper's static budget AND the served tail under load.

Part 1 (§5 latency claim, "operational runtime of less than 25 ns in
simulation"): on silicon the BDT decision function is one combinational
pass; its latency is (logic depth) x (per-LUT+routing delay). We report
the synthesized netlist's combinational depth and the implied latency at
the 28nm ASIC's 200 MHz P&R constraint plus a per-LUT delay model
(~1.0 ns/level at 28nm incl. routing, matching the paper's <25 ns for a
~12-20 level module).

Part 2 (deadline-aware serving, ``bench_deadline``): an OPEN-LOOP bursty
load harness against the ReadoutServer — arrivals come from a Poisson or
square-wave process at a controlled rate regardless of how fast the
server drains (the closed-loop bench can never overload itself; an open
loop can). The harness self-calibrates: it measures the closed-loop
sustainable rate and the 1x-rate p99 first, derives the deadline budget
from them, then drives 2x-sustainable overload under
``overload_policy="shed"`` and ``"degrade"`` and a square-wave burst
profile. Emits the ``fabric.latency_*`` / ``fabric.deadline_*`` records
the CI regression gate validates; ``fabric.deadline_p99``'s
``p99_frac_of_deadline`` is the machine-speed-independent tail metric
the nightly gate thresholds. REPRO_LATENCY_JSON dumps the full record
list (with latency CDFs) standalone for the nightly artifact.
"""
from __future__ import annotations

import os
import time

import numpy as np

from repro.core.bdt import GradientBoostedClassifier
from repro.core.synth import synth_ensemble
from repro.data.smartpixel import SmartPixelConfig, generate, train_test_split

NS_PER_LEVEL_28NM = 1.0   # LUT4 + local routing at 28nm (conservative)
NS_PER_LEVEL_130NM = 2.6

# open-loop harness shape: arrivals come in bunches of _BUNCH frames
# (one bunch crossing illuminates many pixels at once), coalesced into
# micro-batches of up to _BATCH events
_BUNCH = 8
_BATCH = 128


# ---------------------------------------------------------------- arrivals
def poisson_arrivals(rate_hz: float, n: int, rng) -> np.ndarray:
    """n arrival times (seconds from start) of a Poisson process."""
    return np.cumsum(rng.exponential(1.0 / rate_hz, n))


def square_wave_arrivals(
    rate_hz: float, n: int, rng, period_s: float, burst_factor: float = 2.0
) -> np.ndarray:
    """Square-wave load at mean ``rate_hz``: all traffic arrives as a
    Poisson process at ``burst_factor * rate_hz`` during the first
    1/burst_factor of each period, then silence — the bursty profile
    that defeats any tuning done against a smooth mean rate."""
    out: list = []
    hi = burst_factor * rate_hz
    t = 0.0
    while len(out) < n:
        tt, end = t, t + period_s / burst_factor
        while len(out) < n:
            tt += float(rng.exponential(1.0 / hi))
            if tt >= end:
                break
            out.append(tt)
        t += period_s
    return np.asarray(out[:n])


# ----------------------------------------------------------- the harness
def _mk_server(chips, frames, y0, max_latency_s=2e-3, **kw):
    """A warmed-up server with a clean latency ledger: the first
    dispatches pay jit compilation (hundreds of ms), which would
    otherwise dominate every percentile of a short measured run."""
    from repro.launch.readout_server import ReadoutServer, ServerConfig

    cfg = ServerConfig(
        max_batch=_BATCH, max_latency_s=max_latency_s, backend="kernel",
        layout="bitsliced", min_batch=_BATCH // 2, **kw)
    srv = ReadoutServer(chips, cfg)
    for i in range(2 * _BATCH // _BUNCH):
        srv.submit_frames(i % srv.n_chips, *_bunch(i, frames, y0))
        srv.poll()
    srv.flush()
    # touch every pow2 batch bucket (the server pads batches to powers
    # of two) so no run pays a first jit compile mid-measurement — a
    # ~150ms compile spike is many deadlines and poisons the EWMA
    n_ev = _BATCH // 2
    while n_ev >= _BUNCH:
        for i in range(n_ev // _BUNCH):
            srv.submit_frames(i % srv.n_chips, *_bunch(i, frames, y0))
        srv.flush()
        n_ev //= 2
    for k in (4, 2, 1):
        srv.submit_frames(0, frames[:k], y0[:k])
        srv.flush()
    srv.reset_latency_metrics()
    return srv


def _bunch(i: int, frames, y0):
    lo = (i * _BUNCH) % (len(frames) - _BUNCH)
    return frames[lo:lo + _BUNCH], y0[lo:lo + _BUNCH]


def measure_sustainable_rate(chips, frames, y0, n_events: int) -> float:
    """Closed-loop events/s with the SAME driver-side cost structure as
    the open-loop runs (submit_frames per bunch + poll per iteration) —
    the calibration every open-loop rate below is a multiple of."""
    srv = _mk_server(chips, frames, y0)
    t0 = time.perf_counter()
    for i in range(n_events // _BUNCH):
        srv.submit_frames(i % srv.n_chips, *_bunch(i, frames, y0))
        srv.poll()
    srv.flush()
    return n_events / (time.perf_counter() - t0)


def run_open_loop(srv, bunch_times, frames, y0):
    """Drive the server open-loop: bunches are submitted when their
    scheduled arrival time passes, never faster and never gated on the
    server draining. Returns (submitted, shed, results, max_queue)."""
    n_sub = n_shed = max_q = 0
    results = []
    clock = time.monotonic
    start = clock()
    i, nb = 0, len(bunch_times)
    while i < nb:
        if bunch_times[i] <= clock() - start:
            seqs = srv.submit_frames(
                i % srv.n_chips, *_bunch(i, frames, y0))
            n_sub += len(seqs)
            n_shed += sum(1 for s in seqs if s is None)
            i += 1
        results.extend(srv.poll())
        max_q = max(max_q, srv.queue_depth)
    results.extend(srv.flush())
    return n_sub, n_shed, results, max_q


def bench_deadline(note, chips, frames, y0, smoke: bool):
    """The deadline/overload benchmark suite (called from bench_fabric's
    run and the standalone latency module). ``note`` is a
    bench_fabric._Recorder; every record below lands in the bench JSON."""
    n_cal = 1024 if smoke else 2048     # closed-loop calibration events
    rng = np.random.default_rng(20260808)

    # Calibration: the closed-loop rate sets the time scale of EVERYTHING
    # below. batch_s is the full-batch service estimate; the coalesce
    # window lets a 1x stream form near-full batches (an interpret-mode
    # dispatch has a large fixed cost, so undersized batches would turn
    # the nominal 1x rate into accidental overload); the deadline is a
    # fixed multiple of batch_s (machine-speed independent); and every
    # open-loop run spans ~6 deadlines so queues actually reach the
    # admission threshold instead of the run ending first.
    rate = measure_sustainable_rate(chips, frames, y0, n_cal)
    bunch_rate = rate / _BUNCH
    batch_s = _BATCH / rate
    coalesce_s = 1.5 * batch_s
    deadline_us = 8.0 * batch_s * 1e6
    n_run = 96 * _BATCH                 # = 6 deadlines at 2x arrival rate

    # --- 1x Poisson, observe-only: the baseline tail + CDF
    srv = _mk_server(chips, frames, y0, max_latency_s=coalesce_s)
    arr = poisson_arrivals(bunch_rate, n_run // _BUNCH, rng)
    n_sub, n_shed, res, max_q = run_open_loop(srv, arr, frames, y0)
    rep = srv.report()
    lat = rep["latency"]["total"]
    assert n_shed == 0 and len(res) == n_sub, (n_shed, len(res), n_sub)
    note("fabric.latency_p99", lat["p99_us"],
         f"p50_us={lat['p50_us']:.1f};p99_us={lat['p99_us']:.1f};"
         f"p999_us={lat['p999_us']:.1f};mean_us={lat['mean_us']:.1f};"
         f"events={n_sub};arrival=poisson_1x;"
         f"sustainable_ev_s={rate:.0f};batch_service_us={batch_s * 1e6:.0f};"
         f"policy=observe")
    note("fabric.latency_cdf", 0.0,
         f"points={len(rep['latency']['cdf_us'])};arrival=poisson_1x",
         cdf_us=rep["latency"]["cdf_us"],
         queue_wait_p99_us=rep["latency"]["queue_wait"]["p99_us"],
         service_p99_us=rep["latency"]["service"]["p99_us"])

    # --- 2x Poisson overload, policy="shed": admission control + the
    # adaptive coalescer must keep ADMITTED p99 near the deadline and
    # account for every shed event — instead of queueing unboundedly
    srv = _mk_server(chips, frames, y0, max_latency_s=coalesce_s,
                     deadline_us=deadline_us, overload_policy="shed")
    arr = poisson_arrivals(2.0 * bunch_rate, n_run // _BUNCH, rng)
    n_sub, n_shed, res, max_q = run_open_loop(srv, arr, frames, y0)
    rep = srv.report()
    p99 = rep["latency"]["total"]["p99_us"]
    frac = p99 / deadline_us
    coverage = (len(res) + n_shed) / max(n_sub, 1)
    assert abs(coverage - 1.0) < 1e-9, (
        f"shed accounting leak: {len(res)} results + {n_shed} shed "
        f"!= {n_sub} submitted")
    assert rep["deadline"]["shed"] == n_shed, rep["deadline"]
    assert n_shed > 0, (
        "2x sustained overload with a deadline must shed — the queue "
        "would otherwise grow unboundedly")
    # histogram percentiles are exact to ~one log bucket (33%); 1.5x is
    # the hard CI floor, the nightly gate thresholds the baseline value
    assert frac <= 1.5, (
        f"admitted p99 {p99:.0f}us blew the {deadline_us:.0f}us deadline "
        f"by {frac:.2f}x under 2x overload with shedding enabled")
    note("fabric.deadline_p99", p99,
         f"p99_frac_of_deadline={frac:.3f};p99_admitted_us={p99:.1f};"
         f"deadline_us={deadline_us:.1f};policy=shed;arrival=poisson_2x;"
         f"shed_fraction={n_shed / max(n_sub, 1):.3f};"
         f"effective_max_batch={rep['deadline']['effective_max_batch']};"
         f"batch_shrinks={rep['deadline']['batch_shrinks']};"
         f"max_queue_depth={max_q}")
    note("fabric.overload_shed_accounting", 0.0,
         f"coverage={coverage:.6f};submitted={n_sub};"
         f"results={len(res)};shed={n_shed};"
         f"shed_fraction={n_shed / max(n_sub, 1):.3f};"
         f"per_chip_shed={'/'.join(str(c['n_shed']) for c in rep['per_chip'])}")

    # --- 2x Poisson overload, policy="degrade": a tighter budget (3x
    # batch_s — below the pipeline's natural residence) forces sustained
    # misses among admitted events so the hysteretic ladder steps
    srv = _mk_server(
        chips, frames, y0, max_latency_s=coalesce_s,
        deadline_us=3.0 * batch_s * 1e6,
        overload_policy="degrade", scrub_interval=4,
        degrade_window=2 * _BATCH, degrade_enter_frac=0.3,
        degrade_exit_frac=0.02)
    arr = poisson_arrivals(2.0 * bunch_rate, n_run // _BUNCH, rng)
    n_sub, n_shed, res, max_q = run_open_loop(srv, arr, frames, y0)
    rep = srv.report()
    lad = rep["deadline"]["ladder"]
    max_level = max((t["to_level"] for t in lad["transitions"]), default=0)
    note("fabric.deadline_ladder", 0.0,
         f"transitions={len(lad['transitions'])};"
         f"final_level={lad['level']};max_level={max_level};"
         f"active_rungs={'/'.join(lad['active_rungs']) or 'none'};"
         f"shed={n_shed};miss_fraction={rep['deadline']['miss_fraction']:.3f};"
         f"deferred_heals_pending={lad['deferred_heals_pending']}")

    # --- square-wave bursts at 1x MEAN rate (2x bursts), policy="shed":
    # the shed fraction under bursts is the graceful-degradation curve's
    # other axis — a smooth 1x load sheds ~nothing, bursts shed the peaks
    srv = _mk_server(chips, frames, y0, max_latency_s=coalesce_s,
                     deadline_us=deadline_us, overload_policy="shed")
    period = 8.0 * batch_s
    arr = square_wave_arrivals(bunch_rate, n_run // _BUNCH, rng, period)
    n_sub, n_shed, res, max_q = run_open_loop(srv, arr, frames, y0)
    rep = srv.report()
    p99 = rep["latency"]["total"]["p99_us"]
    assert len(res) + n_shed == n_sub, (len(res), n_shed, n_sub)
    note("fabric.deadline_square_wave", p99,
         f"p99_frac_of_deadline={p99 / deadline_us:.3f};"
         f"shed_fraction={n_shed / max(n_sub, 1):.3f};"
         f"burst_factor=2.0;period_s={period:.4f};policy=shed;"
         f"max_queue_depth={max_q}")


def run(emit):
    from benchmarks.bench_fabric import _Recorder, _SMOKE

    note = _Recorder(emit)

    data = generate(SmartPixelConfig(n_events=50_000, seed=2024))
    tr, _ = train_test_split(data)
    clf = GradientBoostedClassifier(
        n_estimators=1, max_depth=5, max_leaf_nodes=10, min_samples_leaf=500
    ).fit(tr["features"], tr["label"])
    synth = synth_ensemble(clf.quantized())
    depth = synth.report["depth"]
    lat28 = depth * NS_PER_LEVEL_28NM
    note("latency.bdt_28nm", 0.0,
         f"levels={depth};ns={lat28:.1f};paper=<25ns;meets={lat28 < 25}")
    note("latency.bdt_130nm", 0.0,
         f"levels={depth};ns={depth * NS_PER_LEVEL_130NM:.1f}")
    # one fabric evaluation per 40 MHz bunch crossing needs < 25 ns:
    note("latency.bunch_crossing_budget", 0.0,
         f"budget_ns=25;at_40MHz_period_ns=25;single_pass={lat28 < 25}")

    # the NN alternative on the 4 DSP slices (time-multiplexed): fails the
    # latency budget even if the LUT problem were solved
    from repro.core.nn_baseline import MLPSpec, dsp_schedule

    d = dsp_schedule(MLPSpec())
    note("latency.nn_dsp_schedule", 0.0,
         f"macs={int(d['macs'])};cycles={int(d['cycles'])};"
         f"ns={d['latency_ns']:.0f};meets_25ns={d['meets_25ns']}")

    # --- the served-tail harness (standalone leg of bench_fabric's suite)
    from repro.core.readout import ReadoutChip

    n_fr = 512 if _SMOKE else 2_048
    d2 = generate(SmartPixelConfig(n_events=n_fr, seed=7),
                  return_frames=True)
    chips = [ReadoutChip.build(clf)]
    chips.append(ReadoutChip.build(GradientBoostedClassifier(
        n_estimators=1, max_depth=4, max_leaf_nodes=8, min_samples_leaf=500,
    ).fit(tr["features"], tr["label"])))
    bench_deadline(note, chips, d2["frames"], d2["features"][:, 13],
                   smoke=_SMOKE)

    path = os.environ.get("REPRO_LATENCY_JSON", "")
    if path:
        note.dump(path)
