"""Roofline analysis from the dry-run artifacts (DESIGN.md §6).

Reads reports/dryrun/<mesh>/<arch>__<shape>.json (written by
launch/dryrun.py) and derives, per cell:

    compute_s    = HLO_FLOPs/dev   / 197e12          (bf16 peak, TPU v5e)
    memory_s     = HLO_bytes/dev   / 819e9           (HBM bandwidth)
    collective_s = wire_bytes/dev  / 50e9            (ICI per-link, ring)

    bottleneck   = argmax of the three
    MODEL_FLOPS  = 6·N_active·tokens (train) | 2·N_active·tokens (prefill)
                   | 2·N_active·batch (decode)
    usefulness   = MODEL_FLOPS / (HLO_FLOPs/dev × n_dev)
    roofline_frac = ideal_useful_time / max(terms)
                   where ideal_useful_time = MODEL_FLOPS / (n_dev × peak)

roofline_frac is the score reported in EXPERIMENTS.md §Perf: 1.0 means the
step is exactly as fast as its useful model FLOPs allow; redundant compute
(remat, dispatch one-hots), memory- or collective-boundedness all push it
down.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

PEAK = 197e12
HBM = 819e9
ICI = 50e9


def analyze(summary: Dict) -> Dict:
    """Roofline terms for one dry-run cell.

    Primary source: the analytic cost model (benchmarks/analytic.py) — the
    models' exact matmul inventory. XLA's cost_analysis counts while-loop
    bodies once (not x trip count), so with lax.scan over layers and
    microbatches its numbers undercount by ~n_layers x n_mb; they are kept
    as ``hlo_*`` fields (per-iteration lower bounds / cross-checks).
    """
    from benchmarks.analytic import cost as analytic_cost
    from repro.configs import get_arch
    from repro.configs.base import SHAPES

    n_dev = summary["n_devices"]
    cfg = get_arch(summary["arch"])
    shape = SHAPES[summary["shape"]]
    ac = analytic_cost(cfg, shape, n_dev, summary["profile"])

    compute_s = ac.flops_dev / PEAK
    memory_s = ac.bytes_dev / HBM
    coll_s = ac.coll_bytes_dev / ICI
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    bottleneck = max(terms, key=terms.get)

    n_act = summary["active_params"]
    # the input embedding is a lookup, not a matmul: subtract its params
    # from the 6ND/2ND counting (tied embeddings stay — the tied matrix IS
    # the head matmul). Without this, small-vocab-heavy archs report
    # usefulness > 1 (mamba2: 1.28).
    if not cfg.tie_embeddings:
        n_act = n_act - cfg.vocab * cfg.d_model
    B, S = summary["global_batch"], summary["seq_len"]
    kind = summary["kind"]
    if kind == "train":
        model_flops = 6.0 * n_act * B * S
    elif kind == "prefill":
        model_flops = 2.0 * n_act * B * S
    else:
        model_flops = 2.0 * n_act * B
    ideal_s = model_flops / (n_dev * PEAK)
    step_bound = max(terms.values())
    return {
        **{k: v for k, v in summary.items() if k in (
            "arch", "shape", "mesh", "kind", "n_devices", "fits_hbm",
            "num_microbatches", "act_shard", "profile")},
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": coll_s,
        "bottleneck": bottleneck,
        "model_flops": model_flops,
        "analytic_flops_global": ac.flops_dev * n_dev,
        "usefulness": model_flops / max(ac.flops_dev * n_dev, 1e-9),
        "roofline_frac": ideal_s / max(step_bound, 1e-12),
        "hlo_flops_per_device_1iter": summary["flops_per_device"],
        "hlo_coll_wire_1iter": summary["collective_wire_bytes_per_device"],
        "peak_gib": summary["memory"].get("peak_bytes", 0) / 2**30,
    }


def load_all(mesh_dir: str) -> List[Dict]:
    rows = []
    for f in sorted(glob.glob(os.path.join(mesh_dir, "*.json"))):
        with open(f) as fh:
            rows.append(analyze(json.load(fh)))
    return rows


def table(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | bottleneck | compute_s | memory_s | coll_s | "
           "useful | roofline | fits |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        lines.append(
            f"| {r['arch']} | {r['shape']} | **{r['bottleneck']}** | "
            f"{r['compute_s']:.3e} | {r['memory_s']:.3e} | "
            f"{r['collective_s']:.3e} | {r['usefulness']:.2f} | "
            f"{r['roofline_frac']:.3f} | {'Y' if r.get('fits_hbm') else 'N'} |"
        )
    return "\n".join(lines)


def run(emit, mesh_dir: str = "reports/dryrun/single_pod_16x16"):
    rows = load_all(mesh_dir)
    if not rows:
        emit("roofline.no_data", 0.0, f"run launch/dryrun.py first ({mesh_dir})")
        return
    for r in rows:
        emit(
            f"roofline.{r['arch']}.{r['shape']}",
            max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e6,
            f"bottleneck={r['bottleneck']};roofline_frac={r['roofline_frac']:.3f};"
            f"useful={r['usefulness']:.2f};fits={r.get('fits_hbm')}",
        )
    md = table(rows)
    out = os.path.join("reports", "roofline_" + os.path.basename(mesh_dir) + ".md")
    os.makedirs("reports", exist_ok=True)
    with open(out, "w") as f:
        f.write("# Roofline — " + mesh_dir + "\n\n" + md + "\n")
    emit("roofline.table_written", 0.0, out)


if __name__ == "__main__":
    import sys

    d = sys.argv[1] if len(sys.argv) > 1 else "reports/dryrun/single_pod_16x16"
    rows = load_all(d)
    print(table(rows))
