"""Per-layout serving sweep: the layout x band x redundancy matrix.

Runs the SAME scored serving dispatch (``fabric_eval_multi_scored``)
through every packing the server can be configured with — layout in
{matmul, bitsliced} x band in {dense, auto} x redundancy in
{none, tmr}, plus the word-domain sparse-egress cell for the bit-sliced
packings — asserting bit-exactness against the golden model in every
cell and recording events/s per cell. The whole matrix lands in
``LAYOUT_matrix.json`` (override with REPRO_LAYOUT_JSON), uploaded
nightly by CI as the ``LAYOUT-matrix`` artifact so layout-relative
throughput trends are archived per jax leg.

Timing caveat: the matmul cells run Pallas interpret mode on CPU, so
their events/s is a lower bound; cross-cell *ratios* on the same runner
are still meaningful (that is what the artifact is for).
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core.bdt import GradientBoostedClassifier
from repro.core.readout import ReadoutChip
from repro.data.smartpixel import SmartPixelConfig, generate, train_test_split
from repro.kernels.lut_eval import ops as lut_ops
from repro.launch.mesh import make_readout_mesh
from repro.parallel.compression import sparse_trigger_unpack

_SMOKE = os.environ.get("REPRO_BENCH_SMOKE", "") == "1"
_JSON_PATH = os.environ.get("REPRO_LAYOUT_JSON", "LAYOUT_matrix.json")


def run(emit):
    n_events = 4_000 if _SMOKE else 20_000
    data = generate(SmartPixelConfig(n_events=n_events, seed=2026))
    tr, te = train_test_split(data)
    clf = GradientBoostedClassifier(
        n_estimators=1, max_depth=5, max_leaf_nodes=10, min_samples_leaf=500,
    ).fit(tr["features"], tr["label"])
    chip = ReadoutChip.build(clf)
    B = 256 if _SMOKE else 1024
    X = te["features"][:B]
    X_raw = chip.golden.quantize_features(X)
    bits = chip.encode_features(X)[None]
    golden = chip.golden.decision_function_raw(X_raw)
    # cut at the median score (not the chip's calibrated trigger) so the
    # sparse cells compact a non-trivial keep set in every matrix run
    thr = np.array([int(np.median(golden))], np.int32)
    kept = golden <= int(thr[0])
    mesh = make_readout_mesh(1)

    cells = []
    for layout in ("matmul", "bitsliced"):
        for band, band_label in ((False, "dense"), (None, "auto")):
            for red in ("none", "tmr"):
                stack = lut_ops.pack_fabrics(
                    [chip.config], band=band, redundancy=red, layout=layout)
                w = lut_ops.decode_plan([chip.config], stack.n_outputs)

                def go(stack=stack, w=w):
                    s, k, d = lut_ops.fabric_eval_multi_scored(
                        stack, bits, w, thr, mesh=mesh)
                    return np.asarray(s), np.asarray(k), np.asarray(d)

                go()            # warmup / jit
                t0 = time.perf_counter()
                score, keep, dis = go()
                t = time.perf_counter() - t0
                exact = bool(np.array_equal(score[0], golden)
                             and np.array_equal(keep[0], kept)
                             and not dis.any())
                assert exact, f"{layout}/{band_label}/{red} diverged"
                cells.append({
                    "layout": layout, "band": band_label,
                    "banded": bool(stack.banded), "band_k": int(stack.band_k),
                    "redundancy": red, "egress": "dense",
                    "events_per_s": round(B / t, 1),
                    "us_per_call": round(t * 1e6, 2),
                    "bit_exact_vs_golden": exact,
                })
                emit(f"layout.{layout}_{band_label}_{red}_{B}ev", t * 1e6,
                     f"events_per_s={B / t:.0f};"
                     f"banded={str(stack.banded).lower()};"
                     f"band_k={stack.band_k};bit_exact_vs_golden=true")

                if not stack.bitsliced:
                    continue
                # word-domain sparse-egress cell: only the bit-sliced
                # packings have a word form to compact in
                def go_sp(stack=stack, w=w):
                    c, i, v, d = lut_ops.fabric_eval_multi_scored_sparse(
                        stack, bits, w, thr, mesh=mesh)
                    return (np.asarray(c), np.asarray(i), np.asarray(v),
                            np.asarray(d))

                go_sp()
                t0 = time.perf_counter()
                count, idx, vals, dis = go_sp()
                t = time.perf_counter() - t0
                s2, k2 = sparse_trigger_unpack(idx, vals, (1, B))
                exact = bool(int(count) == int(kept.sum())
                             and np.array_equal(k2[0], kept)
                             and np.array_equal(s2[0], golden * kept)
                             and not dis.any())
                assert exact, f"{layout}/{band_label}/{red} sparse diverged"
                cells.append({
                    "layout": layout, "band": band_label,
                    "banded": bool(stack.banded), "band_k": int(stack.band_k),
                    "redundancy": red, "egress": "sparse",
                    "events_per_s": round(B / t, 1),
                    "us_per_call": round(t * 1e6, 2),
                    "fraction_kept": round(int(count) / B, 4),
                    "bit_exact_vs_golden": exact,
                })
                emit(f"layout.{layout}_{band_label}_{red}_sparse_{B}ev",
                     t * 1e6,
                     f"events_per_s={B / t:.0f};"
                     f"fraction_kept={int(count) / B:.3f};"
                     f"bit_exact_vs_golden=true")

    doc = {"benchmark": "layout_matrix", "smoke": _SMOKE,
           "batch_events": B, "cells": cells}
    with open(_JSON_PATH, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run(lambda name, us, derived="": print(
        f"{name},{us:.2f},{derived}", flush=True))
