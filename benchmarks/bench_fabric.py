"""Fabric execution throughput: host oracle vs Pallas kernels (events/s).

Covers the paper's bring-up firmware (counter §2.4.1/4.4.1, loopback
§4.4.3) as functional benchmarks and the BDT classifier as the throughput
benchmark. Kernels run in interpret mode on CPU (compiled on TPU), so the
derived events/s here is a CPU lower bound; the TPU-side roofline is in
benchmarks/roofline.py.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core.bdt import GradientBoostedClassifier
from repro.core.fabric import FABRIC_28NM, FabricSim, place_and_route
from repro.core.netlist import counter_netlist, loopback_netlist
from repro.core.readout import ReadoutChip
from repro.core.synth import synth_ensemble
from repro.data.smartpixel import SmartPixelConfig, generate, train_test_split
from repro.kernels.bdt_infer import ops as bdt_ops
from repro.kernels.lut_eval import ops as lut_ops


def _time(fn, *args, reps=3):
    fn(*args)  # warmup / jit
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    return (time.perf_counter() - t0) / reps, out


def run(emit):
    # --- bring-up firmware
    nl = counter_netlist(16)
    cfgf = place_and_route(nl, FABRIC_28NM)
    sim = FabricSim(cfgf)
    t, _ = _time(lambda: sim.run(np.zeros((1, 0)), n_cycles=1000))
    emit("fabric.counter_1000cycles", t * 1e6, "cycles_per_s=%.0f" % (1000 / t))

    lb = place_and_route(loopback_netlist(8), FABRIC_28NM)
    simlb = FabricSim(lb)
    ins = np.random.default_rng(0).integers(0, 2, (64, 200, 10)).astype(np.uint8)
    t, _ = _time(lambda: simlb.run(ins, n_cycles=200))
    emit("fabric.loopback_64x200", t * 1e6, "beats_per_s=%.0f" % (64 * 200 / t))

    # --- BDT classifier throughput: host sim vs lut_eval vs bdt_infer
    data = generate(SmartPixelConfig(n_events=60_000, seed=2024))
    tr, te = train_test_split(data)
    clf = GradientBoostedClassifier(
        n_estimators=1, max_depth=5, max_leaf_nodes=10, min_samples_leaf=500
    ).fit(tr["features"], tr["label"])
    chip = ReadoutChip.build(clf)
    X = te["features"][:8192]
    X_raw = chip.golden.quantize_features(X)
    bits = chip.synth.encode_inputs(X_raw)

    t_host, _ = _time(lambda: FabricSim(chip.config).run(bits))
    emit("fabric.bdt_hostsim_8192ev", t_host * 1e6,
         f"events_per_s={8192 / t_host:.0f}")

    packed = lut_ops.pack_fabric(chip.config)
    t_kern, out = _time(lambda: np.asarray(lut_ops.fabric_eval(packed, bits)))
    emit("fabric.bdt_lut_eval_kernel_8192ev", t_kern * 1e6,
         f"events_per_s={8192 / t_kern:.0f};interpret_mode=cpu")

    ens_packed = bdt_ops.pack_ensemble(chip.golden, n_features=14)
    xi = X_raw.astype(np.int32)
    t_tree, _ = _time(lambda: np.asarray(bdt_ops.bdt_infer(ens_packed, xi)))
    emit("fabric.bdt_infer_kernel_8192ev", t_tree * 1e6,
         f"events_per_s={8192 / t_tree:.0f};speedup_vs_fabric={t_kern / t_tree:.1f}x")

    # full front-end path: frames -> features (yprofile kernel) -> fabric
    from repro.kernels.yprofile import ops as yp_ops

    d2 = generate(SmartPixelConfig(n_events=2_048, seed=7), return_frames=True)
    t_fe, feats = _time(lambda: np.asarray(
        yp_ops.yprofile(d2["frames"], d2["features"][:, 13])))
    emit("fabric.yprofile_kernel_2048ev", t_fe * 1e6,
         f"events_per_s={2048 / t_fe:.0f}")

    # exactness cross-check while we're here
    got = chip.synth.decode_outputs(out)
    want = chip.golden.decision_function_raw(X_raw)
    emit("fabric.kernel_exactness", 0.0,
         f"match={float((got == want).mean()):.4f};paper=1.0")

    # --- multi-chip streaming: events/s vs chip count, ONE batched dispatch
    from repro.core.fabric import MultiFabricSim

    chip_pool = [chip] + [
        ReadoutChip.build(
            GradientBoostedClassifier(
                n_estimators=1, max_depth=5 - (i % 2),
                max_leaf_nodes=10 - (i % 3), min_samples_leaf=500,
            ).fit(tr["features"], tr["label"])
        )
        for i in range(1, 4)
    ]
    B = 512  # interpret mode on CPU; TPU runs this compiled at full batch
    for n_chips in (1, 2, 4):
        chips = chip_pool[:n_chips]
        configs = [c.config for c in chips]
        stack = lut_ops.pack_fabrics(configs)
        per_chip_bits = [
            c.synth.encode_inputs(c.golden.quantize_features(
                te["features"][: B]))
            for c in chips
        ]
        sbits = lut_ops.stack_input_bits(stack, per_chip_bits)
        t_multi, mout = _time(
            lambda: np.asarray(lut_ops.fabric_eval_multi(stack, sbits)),
            reps=1)
        ev = n_chips * B
        # bit-exactness vs the per-chip host oracle (hard requirement)
        oracle = MultiFabricSim(configs).run(sbits)
        exact = bool(np.array_equal(np.asarray(mout), oracle))
        emit(f"fabric.multichip_{n_chips}x{B}ev", t_multi * 1e6,
             f"events_per_s={ev / t_multi:.0f};chips={n_chips};"
             f"one_dispatch=true;bit_exact_vs_host={exact}")
        assert exact, f"multi-chip kernel diverged from host oracle ({n_chips} chips)"
