"""Fabric execution throughput: host oracle vs Pallas kernels (events/s).

Covers the paper's bring-up firmware (counter §2.4.1/4.4.1, loopback
§4.4.3) as functional benchmarks, the BDT classifier as the throughput
benchmark, and a deep-ensemble scenario exercising the two optimizations
that keep multi-tree chips fast: banded lut_eval routing (per-level matmul
touches only the fan-in window) and carry-select tree-reduction synthesis
(shallow, reach-bounded adders). The headline BDT kernel record and the
multi-chip/TMR scenarios run the bit-sliced layout (32 events per uint32
lane, LUTs as bitwise mux trees, the TMR vote folded into the same
bitwise pass); the matmul Pallas kernels run in interpret mode on CPU
(compiled on TPU), so their derived events/s is a CPU lower bound; the
TPU-side roofline is in benchmarks/roofline.py.

Besides the CSV rows printed through ``emit``, every record lands in
``BENCH_fabric.json`` (override the path with REPRO_BENCH_JSON) so the
perf trajectory is machine-readable PR-over-PR. REPRO_BENCH_SMOKE=1
shrinks event counts to CI-smoke size.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from repro.core.bdt import GradientBoostedClassifier
from repro.core.fabric import FABRIC_28NM, FabricSim, place_and_route
from repro.core.netlist import counter_netlist, loopback_netlist
from repro.core.readout import ReadoutChip
from repro.core.synth import synth_ensemble
from repro.data.smartpixel import SmartPixelConfig, generate, train_test_split
from repro.kernels.bdt_infer import ops as bdt_ops
from repro.kernels.lut_eval import ops as lut_ops

_SMOKE = os.environ.get("REPRO_BENCH_SMOKE", "") == "1"
_JSON_PATH = os.environ.get("REPRO_BENCH_JSON", "BENCH_fabric.json")
_PROFILE_DIR = os.environ.get("REPRO_BENCH_PROFILE", "")


def _time(fn, *args, reps=3):
    fn(*args)  # warmup / jit
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    return (time.perf_counter() - t0) / reps, out


class _Recorder:
    """Mirrors every emit() row into a machine-readable record list."""

    def __init__(self, emit):
        self._emit = emit
        self.records = []

    def __call__(self, name: str, us: float, derived: str = "", **fields):
        if fields and not derived:
            derived = ";".join(f"{k}={v}" for k, v in fields.items())
        self._emit(name, us, derived)
        rec = {"name": name, "us_per_call": round(float(us), 2)}
        for part in derived.split(";"):
            if "=" not in part:
                continue
            k, v = part.split("=", 1)
            if v.lower() in ("true", "false"):
                rec[k] = v.lower() == "true"
                continue
            try:
                rec[k] = float(v) if "." in v or "e" in v.lower() else int(v)
            except ValueError:
                rec[k] = v
        rec.update(fields)
        self.records.append(rec)

    def dump(self, path: str):
        doc = {
            "benchmark": "fabric",
            "smoke": _SMOKE,
            "unit": {"us_per_call": "microseconds", "events_per_s": "1/s"},
            "records": self.records,
        }
        with open(path, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
            f.write("\n")


def _bench_deep_ensemble(note, tr, te):
    """Deep-ensemble scenario: n_estimators>=4 — the regime where ripple
    adders levelize ~2-3x deeper and the dense kernel's quadratic cost in
    depth bites. Measures the 2x2 of {ripple, tree-reduction} synthesis x
    {dense, banded} routing, bit-exact against the host oracle."""
    from repro.core.fabric import FABRICS
    from repro.core.quantize import FixedSpec
    import repro.core.tmr  # noqa: F401  (registers efpga_28nm_xl)

    B = 128 if _SMOKE else 512
    spec = FixedSpec(width=16, int_bits=8)
    clf = GradientBoostedClassifier(
        n_estimators=4, max_depth=3, max_leaf_nodes=6, min_samples_leaf=300,
    ).fit(tr["features"], tr["label"])
    ens = clf.quantized(spec)
    fabric = FABRICS["efpga_28nm_xl"]  # 4 trees + adders exceed the 448-cell chip

    synths = {a: synth_ensemble(ens, adder=a) for a in ("ripple", "tree")}
    configs = {a: place_and_route(s.netlist, fabric) for a, s in synths.items()}
    X_raw = ens.quantize_features(te["features"][:B])
    golden = ens.decision_function_raw(X_raw)

    ev_s = {}
    for adder, band, label in [
        ("ripple", False, "dense_ripple"),   # the pre-optimization baseline
        ("ripple", None, "auto_ripple"),     # band rarely pays: reach ~ depth
        ("tree", False, "dense_tree"),
        ("tree", None, "banded_tree"),       # both optimizations together
    ]:
        cfg = configs[adder]
        packed = lut_ops.pack_fabric(cfg, band=band)
        bits = synths[adder].encode_inputs(X_raw)
        t, out = _time(
            lambda p=packed, b=bits: np.asarray(lut_ops.fabric_eval(p, b)),
            reps=1 if _SMOKE else 2,
        )
        got = synths[adder].decode_outputs(np.asarray(out))
        exact = bool(np.array_equal(got, golden))
        assert exact, f"deep-ensemble {label} diverged from golden model"
        ev_s[label] = B / t
        note(
            f"fabric.deep_ensemble4_{label}_{B}ev", t * 1e6,
            f"events_per_s={B / t:.0f};adder={adder};"
            f"banded={str(packed.banded).lower()};band_k={packed.band_k};"
            f"levels={packed.n_levels};fanin_reach={cfg.fanin_reach()};"
            f"sel_rows={packed.sel.shape[1]};n_nets_pad={packed.n_nets_pad};"
            f"bit_exact_vs_golden={str(exact).lower()}",
        )

    depth_r = len(configs["ripple"].level_sizes)
    depth_t = len(configs["tree"].level_sizes)
    speedup = ev_s["banded_tree"] / ev_s["dense_ripple"]
    note(
        "fabric.deep_ensemble4_banded_tree_speedup", 0.0,
        f"speedup={speedup:.2f};"
        f"speedup_vs_dense_ripple={speedup:.2f}x;"
        f"events_per_s_baseline={ev_s['dense_ripple']:.0f};"
        f"events_per_s_optimized={ev_s['banded_tree']:.0f};"
        f"depth_ripple={depth_r};depth_tree={depth_t};"
        f"reach_ripple={configs['ripple'].fanin_reach()};"
        f"reach_tree={configs['tree'].fanin_reach()};"
        f"luts_ripple={synths['ripple'].netlist.n_luts};"
        f"luts_tree={synths['tree'].netlist.n_luts}",
    )
    assert depth_t < depth_r, "tree reduction must cut levelized depth"

    # --- bit-sliced cells: the SAME configs through the word-parallel
    # evaluator (32 events per uint32 lane, 15 bitwise ops per LUT). The
    # deep ensemble is where the matmul kernel's quadratic cost in depth
    # bites hardest, so this speedup is the word-domain headline.
    for adder in ("ripple", "tree"):
        cfg = configs[adder]
        packed = lut_ops.pack_fabric(cfg, layout="bitsliced")
        bits = synths[adder].encode_inputs(X_raw)
        t, out = _time(
            lambda p=packed, b=bits: np.asarray(lut_ops.fabric_eval(p, b)),
            reps=1 if _SMOKE else 2,
        )
        got = synths[adder].decode_outputs(np.asarray(out))
        exact = bool(np.array_equal(got, golden))
        assert exact, f"deep-ensemble bitsliced_{adder} diverged from golden"
        label = f"bitsliced_{adder}"
        ev_s[label] = B / t
        note(
            f"fabric.deep_ensemble4_{label}_{B}ev", t * 1e6,
            f"events_per_s={B / t:.0f};adder={adder};layout=bitsliced;"
            f"banded={str(packed.banded).lower()};band_k={packed.band_k};"
            f"events_per_word=32;bit_exact_vs_golden={str(exact).lower()}",
        )

    bs_speedup = ev_s["bitsliced_tree"] / ev_s["dense_ripple"]
    note(
        "fabric.deep_ensemble4_bitsliced_speedup", 0.0,
        f"speedup={bs_speedup:.2f};"
        f"speedup_vs_dense_ripple={bs_speedup:.2f}x;"
        f"events_per_s_baseline={ev_s['dense_ripple']:.0f};"
        f"events_per_s_bitsliced={ev_s['bitsliced_tree']:.0f};"
        f"matmul_banded_tree_speedup={speedup:.2f}",
    )
    if not _SMOKE:
        assert bs_speedup >= 50.0, (
            f"deep-ensemble bit-sliced eval must be >=50x the dense matmul "
            f"baseline, got {bs_speedup:.1f}x")

    # --- word-domain sparse egress on the deep ensemble: compaction runs
    # on keep WORDS (popcount prefix sums) before any word->event
    # transpose, and the wire bytes (count header + 8 B per kept event vs
    # the 5 B/event dense frame) must track the trigger accept fraction.
    from repro.launch.mesh import make_readout_mesh
    from repro.parallel.compression import (
        DENSE_BYTES_PER_EVENT, SPARSE_BYTES_PER_EVENT, SPARSE_HEADER_BYTES,
        sparse_trigger_unpack,
    )

    cfg = configs["tree"]
    stack = lut_ops.pack_fabrics([cfg], layout="bitsliced")
    w = lut_ops.decode_plan([cfg], stack.n_outputs)
    sbits = synths["tree"].encode_inputs(X_raw)[None]
    mesh = make_readout_mesh(1)
    dense_bytes = B * DENSE_BYTES_PER_EVENT
    ratios = {}
    for pct in (90, 50, 10):
        thr = np.array([int(np.percentile(golden, pct))], np.int32)
        kept = golden <= int(thr[0])
        t, (count, idx, vals, _dis) = _time(
            lambda th=thr: lut_ops.fabric_eval_multi_scored_sparse(
                stack, sbits, w, th, mesh=mesh),
            reps=1 if _SMOKE else 2,
        )
        n_kept = int(np.asarray(count))
        assert n_kept == int(kept.sum()), (pct, n_kept, int(kept.sum()))
        s2, k2 = sparse_trigger_unpack(np.asarray(idx), np.asarray(vals),
                                       (1, B))
        assert np.array_equal(k2[0], kept), f"sparse keep mask p{pct}"
        assert np.array_equal(s2[0], golden * kept), f"sparse scores p{pct}"
        wire = SPARSE_HEADER_BYTES + n_kept * SPARSE_BYTES_PER_EVENT
        ratios[pct] = wire / dense_bytes
        note(
            f"fabric.deep_ensemble4_sparse_p{pct}_{B}ev", t * 1e6,
            f"events_per_s={B / t:.0f};accept_pct={pct};"
            f"fraction_kept={n_kept / B:.3f};layout=bitsliced;"
            f"link_bytes_on_wire={wire};link_bytes_dense={dense_bytes};"
            f"bytes_ratio={wire / dense_bytes:.3f}",
        )
    note(
        "fabric.deep_ensemble4_sparse_egress", 0.0,
        f"bytes_ratio={ratios[10]:.3f};accept_pct=10;"
        f"bytes_ratio_p50={ratios[50]:.3f};bytes_ratio_p90={ratios[90]:.3f};"
        f"dense_bytes={dense_bytes};"
        f"bytes_per_kept_event={SPARSE_BYTES_PER_EVENT};"
        f"header_bytes={SPARSE_HEADER_BYTES}",
    )
    # on-wire bytes must scale with the accept fraction and beat the
    # dense frame at trigger-like (10%) accept rates
    assert ratios[10] <= ratios[50] <= ratios[90], ratios
    assert ratios[10] < ratios[90] and ratios[10] < 1.0, ratios


def _bench_tmr_sparse(note, chip_pool, tr, frames, y0f):
    """SEU-resilient serving + sparse trigger readout: the TMR voted
    server (3 placement-distinct replicas per chip, 2-of-3 device vote)
    and the sparse (indices, scores) host link vs the plain dense path —
    events/s AND measured bytes-on-wire, bit-exact asserted throughout.
    The trigger cut is pinned at the 15th score percentile of the
    TRAINING stream (a link-budget-style cut; the benchmark's frame
    stream then lands at ~27% accept) so the wire numbers reflect a
    pileup-dominated trigger."""
    import copy

    from repro.kernels.yprofile import ops as yp_ops
    from repro.launch.readout_server import ReadoutServer, ServerConfig

    B = 128 if _SMOKE else 512
    n_chips = 2
    chips = []
    for c in chip_pool[:n_chips]:
        # the link-budget cut (15th training-score percentile) on a copy
        # so the other scenarios keep their calibrated thresholds
        c2 = copy.copy(c)
        raw = c2.golden.decision_function_raw(
            c2.golden.quantize_features(tr["features"][:2000]))
        c2.score_threshold_raw = int(np.percentile(raw, 15))
        chips.append(c2)
    fr = frames[:B]
    z = y0f[:B]
    feats = np.asarray(yp_ops.yprofile(fr, z, batch_tile=128))
    golden = {
        i: c.golden.decision_function_raw(c.golden.quantize_features(feats))
        for i, c in enumerate(chips)
    }

    def serve(redundancy, sparse):
        # bit-sliced fabric evaluation: the replicated stage is 15 bitwise
        # ops/LUT over 32-event words, so the voted path no longer pays
        # the 8.3x matmul-replication penalty
        srv = ReadoutServer(chips, ServerConfig(
            max_batch=n_chips * B, max_latency_s=1e9, backend="kernel",
            redundancy=redundancy, sparse=sparse, layout="bitsliced"))
        def go():
            for i in range(n_chips):
                srv.submit_frames(i, fr, z)
            return srv.flush()
        t, res = _time(go, reps=1)
        return srv, t, res

    ev = n_chips * B
    results = {}
    for label, red, sp in [("plain", "none", False),
                           ("tmr", "tmr", False),
                           ("tmr_sparse", "tmr", True)]:
        srv, t, res = serve(red, sp)
        rep = srv.report()
        results[label] = (t, res, rep)
        # bit-exactness: every returned score equals the golden model's
        # (chip i's events are seqs i*B .. i*B+B-1, so pos = seq % B)
        for r in res:
            assert r.score_raw == golden[r.chip][r.seq % B], (label, r.seq)
        note(
            f"fabric.tmr_sparse_{label}_{ev}ev", t * 1e6,
            f"events_per_s={ev / t:.0f};redundancy={red};"
            f"sparse={str(sp).lower()};chips={n_chips};"
            f"layout=bitsliced;n_results={len(res)};"
            f"link_bytes_on_wire={rep['link_bytes']['on_wire']};"
            f"bit_exact_vs_golden=true",
        )

    t_plain = results["plain"][0]
    t_tmr = results["tmr"][0]
    rep_sp = results["tmr_sparse"][2]
    note(
        "fabric.tmr_sparse_link_bytes", 0.0,
        f"link_bytes_sparse={rep_sp['link_bytes']['on_wire']};"
        f"link_bytes_plain={rep_sp['link_bytes']['dense_equivalent']};"
        f"wire_reduction={rep_sp['link_bytes']['wire_reduction']:.2f};"
        f"fraction_kept={rep_sp['fraction_kept']:.3f};"
        f"tmr_overhead_vs_plain={t_tmr / t_plain:.2f};"
        f"seu_disagreements={rep_sp['seu_disagreement_total']}",
    )
    assert (rep_sp["link_bytes"]["on_wire"]
            < rep_sp["link_bytes"]["dense_equivalent"]), rep_sp["link_bytes"]

    # the headline resilience-cost record: TMR throughput overhead on the
    # served path with the bit-sliced evaluator (vote folded into the
    # word-parallel bitwise pass) — was 8.3x with the matmul layouts
    overhead = t_tmr / t_plain
    note(
        "fabric.bitsliced_tmr_overhead", 0.0,
        f"tmr_overhead={overhead:.2f};efficiency={1 / overhead:.3f};"
        f"layout=bitsliced;matmul_baseline_overhead=8.3;"
        f"events_per_s_plain={ev / t_plain:.0f};"
        f"events_per_s_tmr={ev / t_tmr:.0f}",
    )
    assert overhead <= 2.0, (
        f"bit-sliced TMR overhead must be <=2x plain, got {overhead:.2f}x")


def _bench_scrub(note, chip_pool, frames, y0f):
    """Background config-memory scrubbing (readback -> CRC verify -> heal):
    (1) the sustained-throughput cost of scrubbing at the documented
    default interval on a TMR frame stream — the <5% budget the interval
    was chosen for — and (2) mean-time-to-heal under a Poisson
    configuration-fault injector with disagreement-steered scrubbing.
    Both are `fabric.scrub_*` records the CI regression gate validates."""
    from repro.launch.readout_server import (
        DEFAULT_SCRUB_INTERVAL, ReadoutServer, ServerConfig,
    )

    B = 128                     # batch_tile floor: smaller batches pad up
    n_batches = 4 if _SMOKE else 8
    n_chips = 2
    chips = chip_pool[:n_chips]
    fr = frames[:B]
    z = y0f[:B]

    def make(scrub_interval, scrub_mode="steered"):
        return ReadoutServer(chips, ServerConfig(
            max_batch=n_chips * B, max_latency_s=1e9, backend="kernel",
            redundancy="tmr", scrub_interval=scrub_interval,
            scrub_mode=scrub_mode))

    def stream(srv, n):
        for _ in range(n):
            for c in range(n_chips):
                srv.submit_frames(c, fr, z)
            srv.poll()
        srv.flush()

    # --- scrub overhead on a sustained stream (default interval)
    ev = n_chips * B * n_batches
    ev_s = {}
    for label, interval in [("off", None), ("on", DEFAULT_SCRUB_INTERVAL)]:
        srv = make(interval)
        stream(srv, 2)          # warmup: jit + first readback
        t0 = time.perf_counter()
        stream(srv, n_batches)
        t = time.perf_counter() - t0
        ev_s[label] = ev / t
        rep = srv.report()["scrub"]
        note(
            f"fabric.scrub_{label}_{ev}ev", t * 1e6,
            f"events_per_s={ev / t:.0f};redundancy=tmr;chips={n_chips};"
            f"scrub_interval={interval if interval else 0};"
            f"scrub_steps={rep['steps']};"
            f"frames_scrubbed={rep['frames_scrubbed']};"
            f"detections={rep['detections']}",
        )
    ratio = ev_s["on"] / ev_s["off"]
    note(
        "fabric.scrub_overhead", 0.0,
        f"events_per_s_ratio={ratio:.3f};"
        f"overhead_frac={max(0.0, 1.0 - ratio):.3f};"
        f"target_overhead_frac=0.05;"
        f"interval={DEFAULT_SCRUB_INTERVAL};"
        f"events_per_s_scrub_off={ev_s['off']:.0f};"
        f"events_per_s_scrub_on={ev_s['on']:.0f}",
    )

    # --- mean-time-to-heal under a Poisson fault injector: one
    # outstanding fault at a time (unambiguous attribution), arrivals
    # thinned per batch, heal detected by the report's scrub counter
    rng = np.random.default_rng(20260726)
    n_mtth = 10 if _SMOKE else 24
    rate = 0.3
    srv = make(2)               # tighter interval bounds the rr worst case
    stream(srv, 1)              # warmup
    outstanding = None
    det_seen = srv.report()["scrub"]["detections"]
    heal_batches = []
    n_injected = 0
    for bi in range(n_mtth):
        # Poisson-thinned arrivals, one outstanding fault at a time; the
        # first arrival is forced so even the smoke run measures a heal
        if outstanding is None and (n_injected == 0 or rng.random() < rate):
            slot = int(rng.integers(0, n_chips))
            replica = int(rng.integers(0, srv.n_replicas))
            cfg = srv.chips[slot].config
            srv.inject_seu(slot, replica, int(rng.integers(0, cfg.n_luts)),
                           int(rng.integers(0, 16)))
            outstanding = bi
            n_injected += 1
        stream(srv, 1)
        det = srv.report()["scrub"]["detections"]
        if outstanding is not None and det > det_seen:
            heal_batches.append(bi - outstanding + 1)
            det_seen = det
            outstanding = None
    rep = srv.report()["scrub"]
    mean_heal = float(np.mean(heal_batches)) if heal_batches else 0.0
    note(
        "fabric.scrub_mtth", 0.0,
        f"mean_batches_to_heal={mean_heal:.2f};"
        f"max_batches_to_heal={max(heal_batches, default=0)};"
        f"faults_injected={n_injected};faults_healed={len(heal_batches)};"
        f"healed_bits={rep['healed_bits']};"
        f"poisson_rate_per_batch={rate};scrub_interval=2;mode=steered;"
        f"detection_latency_mean_dispatches="
        f"{rep['detection_latency_dispatches']['mean']:.2f}",
    )
    assert len(heal_batches) == n_injected or outstanding is not None, (
        "scrubber lost track of an injected fault")


def run(emit):
    """Run the fabric suite. When ``--profile DIR`` (or
    REPRO_BENCH_PROFILE=DIR) is set, the whole suite runs under a
    ``jax.profiler`` trace written to DIR — open it with
    ``tensorboard --logdir DIR`` or xprof to see the per-dispatch
    timeline (word-domain eval, sparse compaction, donation reuse)."""
    if _PROFILE_DIR:
        import jax

        jax.profiler.start_trace(_PROFILE_DIR)
    try:
        _run(emit)
    finally:
        if _PROFILE_DIR:
            jax.profiler.stop_trace()


def _run(emit):
    note = _Recorder(emit)

    # --- bring-up firmware
    n_cycles = 100 if _SMOKE else 1000
    nl = counter_netlist(16)
    cfgf = place_and_route(nl, FABRIC_28NM)
    sim = FabricSim(cfgf)
    t, _ = _time(lambda: sim.run(np.zeros((1, 0)), n_cycles=n_cycles))
    note(f"fabric.counter_{n_cycles}cycles", t * 1e6,
         "cycles_per_s=%.0f" % (n_cycles / t))

    lb = place_and_route(loopback_netlist(8), FABRIC_28NM)
    simlb = FabricSim(lb)
    n_lanes, n_beats = (16, 50) if _SMOKE else (64, 200)
    ins = np.random.default_rng(0).integers(
        0, 2, (n_lanes, n_beats, 10)).astype(np.uint8)
    t, _ = _time(lambda: simlb.run(ins, n_cycles=n_beats))
    note(f"fabric.loopback_{n_lanes}x{n_beats}", t * 1e6,
         "beats_per_s=%.0f" % (n_lanes * n_beats / t))

    # --- BDT classifier throughput: host sim vs lut_eval vs bdt_infer
    n_events = 6_000 if _SMOKE else 60_000
    data = generate(SmartPixelConfig(n_events=n_events, seed=2024))
    tr, te = train_test_split(data)
    clf = GradientBoostedClassifier(
        n_estimators=1, max_depth=5, max_leaf_nodes=10, min_samples_leaf=500
    ).fit(tr["features"], tr["label"])
    chip = ReadoutChip.build(clf)
    n_ev = 512 if _SMOKE else 8192
    X = te["features"][:n_ev]
    X_raw = chip.golden.quantize_features(X)
    bits = chip.synth.encode_inputs(X_raw)

    t_host, _ = _time(lambda: FabricSim(chip.config).run(bits))
    note(f"fabric.bdt_hostsim_{n_ev}ev", t_host * 1e6,
         f"events_per_s={n_ev / t_host:.0f}")

    # hot-swap cost = host-side pack latency (vectorized numpy scatter)
    t_pack, packed = _time(lambda: lut_ops.pack_fabric(chip.config))
    note("fabric.pack_fabric_latency", t_pack * 1e6,
         f"packs_per_s={1 / t_pack:.0f};banded={str(packed.banded).lower()};"
         f"band_k={packed.band_k};levels={packed.n_levels}")

    t_mm, out = _time(lambda: np.asarray(lut_ops.fabric_eval(packed, bits)))
    note(f"fabric.bdt_lut_eval_matmul_{n_ev}ev", t_mm * 1e6,
         f"events_per_s={n_ev / t_mm:.0f};interpret_mode=cpu;"
         f"banded={str(packed.banded).lower()}")

    # --- bit-sliced evaluation: 32 events per uint32 lane, each LUT a
    # 15-op bitwise mux tree over whole words (traceable XLA, no Pallas
    # interpret penalty). THE headline kernel record — bit-exact vs the
    # matmul path and the independent word-parallel host oracle.
    from repro.core.fabric import BitslicedSim

    packed_bs = lut_ops.pack_fabric(chip.config, layout="bitsliced")
    t_kern, out_bs = _time(
        lambda: np.asarray(lut_ops.fabric_eval(packed_bs, bits)))
    assert np.array_equal(out_bs, np.asarray(out)), \
        "bitsliced diverged from matmul lut_eval"
    assert np.array_equal(out_bs, BitslicedSim(chip.config).run(bits)), \
        "bitsliced kernel diverged from host word oracle"
    bs_speedup = t_mm / t_kern
    note(f"fabric.bdt_lut_eval_kernel_{n_ev}ev", t_kern * 1e6,
         f"events_per_s={n_ev / t_kern:.0f};layout=bitsliced;"
         f"events_per_word=32;bit_exact_vs_matmul=true;"
         f"speedup_vs_matmul={bs_speedup:.1f}x")
    note("fabric.bitsliced_speedup", 0.0,
         f"speedup={bs_speedup:.2f};"
         f"events_per_s_matmul={n_ev / t_mm:.0f};"
         f"events_per_s_bitsliced={n_ev / t_kern:.0f}")
    assert bs_speedup >= 10.0, (
        f"bit-sliced lut_eval must be >=10x the matmul kernel, "
        f"got {bs_speedup:.1f}x")

    ens_packed = bdt_ops.pack_ensemble(chip.golden, n_features=14)
    xi = X_raw.astype(np.int32)
    t_tree, _ = _time(lambda: np.asarray(bdt_ops.bdt_infer(ens_packed, xi)))
    note(f"fabric.bdt_infer_kernel_{n_ev}ev", t_tree * 1e6,
         f"events_per_s={n_ev / t_tree:.0f};speedup_vs_fabric={t_kern / t_tree:.1f}x")

    # full front-end path: frames -> features (yprofile kernel) -> fabric
    from repro.kernels.yprofile import ops as yp_ops

    n_fe = 512 if _SMOKE else 2_048
    d2 = generate(SmartPixelConfig(n_events=n_fe, seed=7), return_frames=True)
    t_fe, feats = _time(lambda: np.asarray(
        yp_ops.yprofile(d2["frames"], d2["features"][:, 13])))
    note(f"fabric.yprofile_kernel_{n_fe}ev", t_fe * 1e6,
         f"events_per_s={n_fe / t_fe:.0f}")

    # --- fused on-device frontend: frames -> features -> bits -> score in
    # ONE dispatch (kernels/frontend.py) vs the host-featurize baseline
    # (featurizer materialized, numpy quantize+pack, then the SAME packed
    # lut_eval backend) — the paper's at-source pipeline end to end.
    from repro.kernels import frontend as fe

    frames, y0f = d2["frames"], d2["features"][:, 13]
    # the fabric stage of the fused dispatch runs the bit-sliced layout
    # (PR 6's evaluator) — the featurizer/encode stages are unchanged, so
    # the fused speedup now reflects the sliced fabric too
    front = fe.pack_frontend([chip.config], [chip.frontend_spec()],
                             layout="bitsliced", batch_tile=128)

    def host_featurize_path():
        feats = np.asarray(yp_ops.yprofile(frames, y0f, batch_tile=128))
        return np.asarray(
            lut_ops.fabric_eval(packed, chip.encode_features(feats)))

    def fused_path():
        s, k = front.score_frames(frames[None], y0f[None])
        return np.asarray(s), np.asarray(k)

    t_staged, staged_out = _time(host_featurize_path)
    staged_scores = chip.synth.decode_outputs(np.asarray(staged_out))
    t_fused, (fscores, _fkeep) = _time(fused_path)
    fexact = bool(np.array_equal(fscores[0], staged_scores))
    assert fexact, "fused frontend diverged from the staged host path"
    note(f"fabric.frames_host_featurize_{n_fe}ev", t_staged * 1e6,
         f"events_per_s={n_fe / t_staged:.0f};"
         f"stages=featurize+encode+lut_eval;host_materialized=true")
    note(f"fabric.frames_fused_{n_fe}ev", t_fused * 1e6,
         f"events_per_s={n_fe / t_fused:.0f};one_dispatch=true;"
         f"sharded_chips=1;layout={front.stack.layout};"
         f"bit_exact_vs_staged={str(fexact).lower()}")
    note("fabric.frames_fused_speedup", 0.0,
         f"speedup={t_staged / t_fused:.2f};"
         f"events_per_s_host_featurize={n_fe / t_staged:.0f};"
         f"events_per_s_fused={n_fe / t_fused:.0f}")

    # exactness cross-check while we're here
    got = chip.synth.decode_outputs(out)
    want = chip.golden.decision_function_raw(X_raw)
    note("fabric.kernel_exactness", 0.0,
         f"match={float((got == want).mean()):.4f};paper=1.0")

    # --- multi-chip streaming: events/s vs chip count, ONE batched dispatch
    from repro.core.fabric import MultiFabricSim

    chip_pool = [chip] + [
        ReadoutChip.build(
            GradientBoostedClassifier(
                n_estimators=1, max_depth=5 - (i % 2),
                max_leaf_nodes=10 - (i % 3), min_samples_leaf=500,
            ).fit(tr["features"], tr["label"])
        )
        for i in range(1, 4)
    ]
    B = 128 if _SMOKE else 512  # interpret mode on CPU; TPU compiles full batch
    multichip_ev_s = []
    for n_chips in (1, 2, 4):
        chips = chip_pool[:n_chips]
        configs = [c.config for c in chips]
        # bit-sliced layout: chips are a leading batch axis of ONE fused
        # XLA computation, so events/s grows (not shrinks) with chip count
        stack = lut_ops.pack_fabrics(configs, layout="bitsliced")
        per_chip_bits = [
            c.synth.encode_inputs(c.golden.quantize_features(
                te["features"][: B]))
            for c in chips
        ]
        sbits = lut_ops.stack_input_bits(stack, per_chip_bits)
        t_multi, mout = _time(
            lambda: np.asarray(lut_ops.fabric_eval_multi(stack, sbits)),
            reps=1)
        ev = n_chips * B
        # bit-exactness vs the per-chip host oracle (hard requirement)
        oracle = MultiFabricSim(configs).run(sbits)
        exact = bool(np.array_equal(np.asarray(mout), oracle))
        multichip_ev_s.append(ev / t_multi)
        note(f"fabric.multichip_{n_chips}x{B}ev", t_multi * 1e6,
             f"events_per_s={ev / t_multi:.0f};chips={n_chips};"
             f"one_dispatch=true;layout=bitsliced;"
             f"bit_exact_vs_host={str(exact).lower()}")
        assert exact, f"multi-chip kernel diverged from host oracle ({n_chips} chips)"
    # scaling must be non-decreasing in chip count (0.75 tolerance factor
    # absorbs timer noise on the sub-ms dispatches)
    for i in range(1, len(multichip_ev_s)):
        assert multichip_ev_s[i] >= 0.75 * multichip_ev_s[i - 1], (
            f"multichip events/s decreased with chip count: "
            f"{[f'{v:.0f}' for v in multichip_ev_s]}")

    # --- deep-ensemble: banded routing x tree-reduction synthesis
    _bench_deep_ensemble(note, tr, te)

    # --- TMR voted serving + sparse trigger readout vs the plain path
    _bench_tmr_sparse(note, chip_pool, tr, frames, y0f)

    # --- background config scrubbing: overhead + mean-time-to-heal
    _bench_scrub(note, chip_pool, frames, y0f)

    # --- deadline-aware serving: open-loop bursty load, tail latency,
    # admission-control shed accounting and the degrade ladder
    from benchmarks import bench_latency

    bench_latency.bench_deadline(note, chip_pool[:2], frames, y0f,
                                 smoke=_SMOKE)

    # --- network front door: loopback replay vs in-process serving
    from benchmarks import bench_net

    bench_net.bench_net_scenario(note, chip_pool[:1], frames, y0f,
                                 smoke=_SMOKE)

    # --- elastic multi-tenant fleet: admission latency, evict/re-admit,
    # events/s vs tenant count over the bucketed geometry pools
    from benchmarks import bench_fleet

    bench_fleet.bench_fleet_scenario(note, chip_pool, te, smoke=_SMOKE)

    note.dump(_JSON_PATH)


def main(argv=None) -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--profile", metavar="DIR", default="",
        help="write a jax.profiler trace of the whole suite under DIR "
             "(same as REPRO_BENCH_PROFILE=DIR); tracing adds "
             "per-dispatch overhead, so the suite's timing assertions "
             "can trip under it — use for timeline archaeology, not for "
             "regenerating the committed baseline")
    args = ap.parse_args(argv)
    global _PROFILE_DIR
    if args.profile:
        os.environ["REPRO_BENCH_PROFILE"] = args.profile
        _PROFILE_DIR = args.profile
    print("name,us_per_call,derived")
    run(lambda name, us, derived="": print(
        f"{name},{us:.2f},{derived}", flush=True))


if __name__ == "__main__":
    main()
