"""Fig. 5 / Fig. 10 (power vs clock) + §3 scaling factors."""
from __future__ import annotations

from repro.core.power import (
    area_efficiency_ratio, core_power_ratio, energy_per_inference_nj, sweep,
)


def run(emit):
    for node in ("130nm", "28nm"):
        for row in sweep(node):
            emit(
                f"power.{node}@{int(row['f_mhz'])}MHz", 0.0,
                f"core_mw={row['core_mw']:.1f};io_mw={row['io_mw']:.1f};"
                f"total_mw={row['total_mw']:.1f};readback_ok={int(row['sugoi_readback_ok'])}",
            )
    emit("power.core_ratio@100MHz", 0.0,
         f"ratio={core_power_ratio(100):.2f};paper=2.8")
    emit("power.core_ratio@125MHz", 0.0,
         f"ratio={core_power_ratio(125):.2f};paper=approx_3 (one third)")
    emit("power.area_efficiency_28nm_vs_130nm", 0.0,
         f"ratio={area_efficiency_ratio():.1f};paper=21")
    emit("power.energy_per_inference@200MHz", 0.0,
         f"nj={energy_per_inference_nj('28nm', 200.0, cycles=5):.3f}")
