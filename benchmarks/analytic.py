"""Analytic per-step cost model (FLOPs / HBM bytes / collective bytes).

Why analytic: XLA's ``cost_analysis()`` counts a while-loop body ONCE, not
x trip-count — with lax.scan over layers and microbatches (deliberate, for
512-device compile time) the reported FLOPs undercount by ~n_layers x n_mb.
The models are ours, so the exact matmul inventory is enumerable; the
parsed-HLO collective bytes (hlo_analysis.py) remain as per-iteration
cross-checks.

Conventions:
  * FLOPs: 2·M·N·K per matmul; backward = 2x forward; full remat adds one
    forward recompute -> train multiplier 4, prefill/decode 1.
  * attention scores+AV: 2 * 2 * S_kv_avg * H * hd per query token
    (causal average S/2 for self-attention over the same sequence).
  * bytes: per-device weight traffic (reads per step x bytes) + activation
    traffic (layers x tokens_dev x d_model x dtype x ~10 tensor touches)
    + KV-cache/state traffic for decode.
  * collectives: enumerated from the sharding design (DESIGN.md §5):
    Megatron-SP all-gather/reduce-scatter per block, FSDP param gathers,
    ZeRO-2 grad reduce-scatters, DP gradient reduction, MoE dispatch
    resharding, decode partial-softmax/logit reductions.

All values are per device, per step; terms in seconds come from dividing by
(peak flops, HBM bw, ICI link bw).
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import ArchConfig, ShapeSpec

BF16 = 2
F32 = 4


def _attn_flops_per_token(cfg: ArchConfig, s_kv: float) -> float:
    hd = cfg.resolved_head_dim()
    H, KV = cfg.n_heads, cfg.n_kv_heads
    D = cfg.d_model
    proj = 2 * D * (2 * H * hd + 2 * KV * hd)
    sc = 2 * 2 * s_kv * H * hd
    return proj + sc


def _mlp_flops_per_token(cfg: ArchConfig) -> float:
    mult = 3 if cfg.mlp in ("swiglu", "geglu") else 2
    return 2 * cfg.d_model * cfg.d_ff * mult


def _moe_flops_per_token(cfg: ArchConfig) -> float:
    mult = 3 if cfg.mlp in ("swiglu", "geglu") else 2
    D = cfg.d_model
    routed = (cfg.top_k * cfg.capacity_factor) * mult * 2 * D * cfg.expert_d_ff
    shared = cfg.n_shared_experts * mult * 2 * D * cfg.expert_d_ff
    router = 2 * D * cfg.n_experts
    # dispatch/combine one-hot einsums: 2 * E*C ~= 2 * Tg*k*cf per token
    disp = 2 * 2 * cfg.top_k * cfg.capacity_factor * D
    return routed + shared + router + disp


def _ssm_flops_per_token(cfg: ArchConfig) -> float:
    D = cfg.d_model
    d_in = cfg.ssm_expand * D
    N = cfg.ssm_state
    H = d_in // cfg.ssm_head_dim
    Q = cfg.ssm_chunk
    proj = 2 * D * (2 * d_in + 2 * N + H) + 2 * d_in * D
    conv = 2 * cfg.ssm_conv * (d_in + 2 * N)
    # SSD: intra-chunk (scores QxQ over N + apply over head_dim) + states
    intra = 2 * Q * N + 2 * Q * cfg.ssm_head_dim * 2
    states = 2 * 2 * N * cfg.ssm_head_dim
    return proj + conv + (intra + states) * 1.0


def forward_flops_per_token(cfg: ArchConfig, s_kv: float) -> float:
    """One token through the whole stack (excl. lm head)."""
    L = cfg.n_layers
    if cfg.family == "ssm":
        return L * _ssm_flops_per_token(cfg)
    if cfg.family == "hybrid":
        from repro.models.hybrid import n_shared_applications

        napp = n_shared_applications(cfg)
        return (L * _ssm_flops_per_token(cfg)
                + napp * (_attn_flops_per_token(cfg, s_kv) + _mlp_flops_per_token(cfg)))
    if cfg.family == "moe":
        return L * (_attn_flops_per_token(cfg, s_kv) + _moe_flops_per_token(cfg))
    if cfg.family == "encdec":
        enc = cfg.n_enc_layers * (
            _attn_flops_per_token(cfg, cfg.enc_len) + _mlp_flops_per_token(cfg))
        dec = cfg.n_layers * (
            _attn_flops_per_token(cfg, s_kv)          # self
            + _attn_flops_per_token(cfg, cfg.enc_len)  # cross
            + _mlp_flops_per_token(cfg))
        # enc flops amortized: enc_len tokens vs dec S tokens; fold into dec rate
        return dec + enc * 0  # encoder counted separately in flops()
    return cfg.n_layers * (
        _attn_flops_per_token(cfg, s_kv) + _mlp_flops_per_token(cfg))


def head_flops_per_token(cfg: ArchConfig) -> float:
    return 2 * cfg.d_model * cfg.vocab


@dataclasses.dataclass
class CellCost:
    flops_dev: float
    bytes_dev: float
    coll_bytes_dev: float
    detail: Dict


def param_bytes_dev(cfg: ArchConfig, n_dev: int, profile: str) -> float:
    n = cfg.param_count()
    if profile == "dp":
        return n * BF16  # replicated
    if profile == "tp":
        return n * BF16 / 16
    return n * BF16 / n_dev  # tp_fsdp / fsdp_pure: fully sharded


def cost(cfg: ArchConfig, shape: ShapeSpec, n_dev: int, profile: str) -> CellCost:
    B, S = shape.global_batch, shape.seq_len
    kind = shape.kind
    model_axis = 1 if profile in ("dp", "fsdp_pure") else 16
    dp_world = n_dev // model_axis
    D = cfg.d_model

    if kind == "decode":
        tokens_dev = max(B / max(min(B, dp_world), 1), 1)
        s_kv = S
        f_tok = forward_flops_per_token(cfg, s_kv) + head_flops_per_token(cfg)
        flops_dev = tokens_dev * f_tok / model_axis
        pb = param_bytes_dev(cfg, n_dev, profile)
        # KV cache / state read once per decode step (the decode wall)
        hd = cfg.resolved_head_dim()
        if cfg.family == "ssm":
            cache = cfg.n_layers * B * (cfg.ssm_expand * D // cfg.ssm_head_dim) \
                * cfg.ssm_head_dim * cfg.ssm_state * F32
        elif cfg.family == "hybrid":
            from repro.models.hybrid import n_shared_applications

            cache = (cfg.n_layers * B * (cfg.ssm_expand * D) * cfg.ssm_state * F32
                     + n_shared_applications(cfg) * B * S * cfg.n_kv_heads * hd * 2 * BF16)
        else:
            kvb = 1 if cfg.kv_cache_dtype == "int8" else BF16
            cache = cfg.n_layers * B * S * cfg.n_kv_heads * hd * 2 * kvb
        cache_dev = cache / n_dev if profile not in ("dp", "fsdp_pure") else cache / min(B, n_dev)
        bytes_dev = pb + cache_dev + tokens_dev * D * BF16 * cfg.n_layers * 10 / model_axis
        if profile == "fsdp_pure":
            bytes_dev += cfg.param_count() * BF16  # per-step full param gather
        # collectives: per-layer partial-softmax/proj reductions (TP) tiny;
        # logits all-gather over vocab shards
        coll = 0.0
        if profile == "fsdp_pure":
            coll += cfg.param_count() * BF16 * (n_dev - 1) / n_dev
        elif profile != "dp":
            per_layer = tokens_dev * D * BF16 * 2  # wo/w_down partial sums
            coll = cfg.n_layers * per_layer * 2 * (model_axis - 1) / model_axis
        coll += B / max(dp_world, 1) * cfg.vocab * F32 * (model_axis - 1) / model_axis
        return CellCost(flops_dev, bytes_dev, coll, {
            "tokens_dev": tokens_dev, "cache_dev": cache_dev, "param_dev": pb})

    # train / prefill
    tokens = B * S
    tokens_dev = tokens / dp_world
    s_kv = S / 2  # causal average
    f_tok = forward_flops_per_token(cfg, s_kv) + head_flops_per_token(cfg)
    mult = 4.0 if kind == "train" else 1.0  # fwd + 2x bwd + remat refwd
    flops_dev = tokens_dev * f_tok * mult / model_axis
    if cfg.family == "encdec":
        enc_tok_dev = B * cfg.enc_len / (dp_world if profile != "dp" else n_dev)
        flops_dev += enc_tok_dev * cfg.n_enc_layers * (
            _attn_flops_per_token(cfg, cfg.enc_len / 2) + _mlp_flops_per_token(cfg)
        ) * mult / model_axis

    pb = param_bytes_dev(cfg, n_dev, profile)
    n_mb = max(cfg.num_microbatches, 1) if kind == "train" else max(
        cfg.prefill_microbatches, 1)
    if kind == "train":
        # weights: fwd + remat + bwd reads (x n_mb for the scan) + grad rw + opt rw
        w_traffic = pb * (3 * n_mb + 4)
    else:
        w_traffic = pb * n_mb
    if profile == "fsdp_pure":
        # FSDP gathers the full (bf16) weights each pass
        w_traffic += cfg.param_count() * BF16 * ((3 * n_mb) if kind == "train" else n_mb)
    act_traffic = tokens_dev * D * BF16 * cfg.n_layers * 10 * (
        2.5 if kind == "train" else 1.0)
    if profile != "dp" and cfg.act_shard == "seq":
        act_traffic /= model_axis
    bytes_dev = w_traffic + act_traffic

    # collectives
    coll = 0.0
    ring = (model_axis - 1) / max(model_axis, 1)
    ring_all = (n_dev - 1) / n_dev
    if profile == "fsdp_pure":
        # ZeRO-3 param all-gathers: fwd + remat + bwd (train) or 1x (prefill)
        coll += cfg.param_count() * BF16 * ring_all * (
            3 if kind == "train" else 1)
    elif profile != "dp":
        if cfg.act_shard == "seq":
            # Megatron-SP: AG + RS of (B,S,D) per block entry/exit, x2 blocks
            per_layer = 2 * 2 * (tokens_dev * D * BF16) * ring
        else:
            # TP partial-sum all-reduces after wo / w_down
            per_layer = 2 * 2 * (tokens_dev * D * BF16) * ring
        coll += cfg.n_layers * per_layer * (2 if kind == "train" else 1)
        if profile == "tp_fsdp":
            coll += cfg.param_count() * BF16 / model_axis * ring * (
                (2 if kind == "train" else 1) + (1 if kind == "train" else 0))
    if kind == "train":
        # gradient reduction over the dp axes (ZeRO-2 reduce-scatter ~= 1x)
        gb = BF16 if cfg.grad_accum_dtype == "bfloat16" else F32
        g_bytes = cfg.param_count() * gb / model_axis
        dp_deg = max(dp_world, 2)
        coll += g_bytes * (dp_deg - 1) / dp_deg
    if cfg.family == "moe" and profile != "dp":
        # dispatch/combine resharding (all-to-all equivalent): tokens x D x 2
        coll += 2 * tokens_dev * D * BF16 * ring * (
            2 if kind == "train" else 1) * cfg.n_layers / cfg.n_layers
    # chunked-xent logit reductions
    coll += tokens_dev * F32 * 2  # logsumexp partials over vocab shards

    return CellCost(flops_dev, bytes_dev, coll, {
        "tokens_dev": tokens_dev, "param_dev": pb, "w_traffic": w_traffic,
        "act_traffic": act_traffic})
