"""CI bench-regression gate for BENCH_fabric.json.

Compares a freshly generated benchmark document against the committed
baseline and fails (exit 1) when a tracked headline metric drops by more
than the allowed fraction. Replaces the inline key-existence heredoc that
used to live in .github/workflows/ci.yml.

Two tiers, matching the CI jobs:

  * ``--tier smoke`` (fast tier, REPRO_BENCH_SMOKE=1 numbers): lenient
    key/shape checks only — the smoke run's event counts are too small
    for its timings to be comparable to the full-size baseline, so the
    gate verifies the document structure, that every tracked scenario
    produced its record, and that every tracked metric is present and a
    finite positive number.
  * ``--tier nightly`` (full-size numbers): everything smoke checks PLUS
    the regression thresholds — each tracked metric must be at least
    ``(1 - max_drop)`` of the committed baseline value (default
    max_drop 0.25, i.e. fail on a >25% drop).

Tracked metrics (record name -> field, direction):

  frames_fused_speedup       fabric.frames_fused_speedup        .speedup   ^
  tmr_sparse_wire_reduction  fabric.tmr_sparse_link_bytes       .wire_reduction ^
  deep_ensemble4_speedup     fabric.deep_ensemble4_banded_tree_speedup .speedup ^
  deep_ensemble4_bitsliced_speedup fabric.deep_ensemble4_bitsliced_speedup .speedup ^
  sparse_egress_bytes_ratio  fabric.deep_ensemble4_sparse_egress .bytes_ratio v
  scrub_overhead             fabric.scrub_overhead              .events_per_s_ratio ^
  bitsliced_speedup          fabric.bitsliced_speedup           .speedup   ^
  bitsliced_tmr_efficiency   fabric.bitsliced_tmr_overhead      .efficiency ^
  deadline_p99               fabric.deadline_p99          .p99_frac_of_deadline v
  overload_shed_coverage     fabric.overload_shed_accounting    .coverage  ^
  fleet_warm_admission_speedup fleet.admission_warm             .warm_over_cold ^

Direction ``^`` fails on a drop below ``baseline * (1 - max_drop)``;
direction ``v`` (lower is better) fails on a rise above
``baseline * (1 + max_drop)`` — ``deadline_p99`` tracks the admitted
2x-overload p99 as a FRACTION of the self-calibrated deadline, so it is
machine-speed independent and a >25% rise is a genuine tail-latency
regression, not a slower runner. ``overload_shed_coverage`` is
(results + shed) / submitted under overload — below 1.0 means events
vanished unaccounted, which the open-loop bench itself also asserts.
``sparse_egress_bytes_ratio`` (also ``v``) is on-wire bytes at the
10%-accept trigger as a fraction of the dense frame — a rise means the
word-domain sparse link got fatter per kept event.

For ``scrub_overhead`` the tracked value is the scrub-on/scrub-off
events/s ratio (1.0 = free, the target is >= 0.95): a *drop* in the ratio
means scrubbing got more expensive, which is exactly the regression the
gate exists to catch. ``bitsliced_tmr_efficiency`` is tracked the same
way: it is 1 / (TMR-served / plain-served time) on the bit-sliced layout
(1.0 = the vote is free, the acceptance bar is >= 0.5 i.e. overhead
<= 2x), so a drop means the fused word-majority vote got more expensive.
The shape tier additionally asserts the multichip events/s never
decreases with chip count (0.75 tolerance factor for timer noise) — the
inverse-scaling regression the bit-sliced stack fixed.

Variance caveat: the speedup metrics are same-run ratios of CPU
interpret-mode timings, which are noisy under host contention (>30%
swings observed on a loaded machine; the committed baseline is always
captured idle). ``--max-drop`` is the knob if a nightly runner proves
noisier than the 25% default tolerates — widen it there rather than
committing a noise-low baseline, which would mask real regressions.

Usage:
    python benchmarks/check_regression.py \
        --fresh BENCH_fresh.json --baseline BENCH_fabric.json --tier smoke
"""
from __future__ import annotations

import argparse
import json
import math
import sys
from typing import Dict, List, Tuple

# (metric key, record name, field, direction[, drift slack]) — the
# headline numbers the repo's PR-over-PR perf trajectory is judged by.
# Direction "higher" fails on a drop, "lower" fails on a rise
# (latency-style metrics). The optional 5th element multiplies
# --max-drop for that key alone (noisier metrics get a wider band).
TRACKED: List[Tuple] = [
    ("frames_fused_speedup", "fabric.frames_fused_speedup", "speedup",
     "higher"),
    ("tmr_sparse_wire_reduction", "fabric.tmr_sparse_link_bytes",
     "wire_reduction", "higher"),
    ("deep_ensemble4_speedup", "fabric.deep_ensemble4_banded_tree_speedup",
     "speedup", "higher"),
    ("deep_ensemble4_bitsliced_speedup",
     "fabric.deep_ensemble4_bitsliced_speedup", "speedup", "higher"),
    ("sparse_egress_bytes_ratio", "fabric.deep_ensemble4_sparse_egress",
     "bytes_ratio", "lower"),
    ("scrub_overhead", "fabric.scrub_overhead", "events_per_s_ratio",
     "higher"),
    ("bitsliced_speedup", "fabric.bitsliced_speedup", "speedup", "higher"),
    ("bitsliced_tmr_efficiency", "fabric.bitsliced_tmr_overhead",
     "efficiency", "higher"),
    ("deadline_p99", "fabric.deadline_p99", "p99_frac_of_deadline",
     "lower"),
    ("overload_shed_coverage", "fabric.overload_shed_accounting",
     "coverage", "higher"),
    ("net_loopback_evps", "net.loopback_replay", "frac_of_inprocess",
     "higher"),
    # 2x drift slack: tail-latency percentiles swing more than the
    # throughput ratios even as a median-of-5 (host scheduling noise)
    ("net_e2e_p99_frac", "net.e2e_latency", "p99_frac", "lower", 2.0),
    # warm/cold admission ratio: warm is a pure array swap, cold pays the
    # bucket's one jit compile — a drop means warm admissions started
    # paying compile-path work again. 2x slack: the ratio divides two
    # wall-clock timings of very different magnitude, so it inherits the
    # compile time's run-to-run variance.
    ("fleet_warm_admission_speedup", "fleet.admission_warm",
     "warm_over_cold", "higher", 2.0),
]

# Scenario prefixes that must have produced at least one record each —
# the shape check that catches a silently-skipped benchmark section.
REQUIRED_PREFIXES = [
    "fabric.frames_fused_",
    "fabric.tmr_sparse_",
    "fabric.deep_ensemble4_",
    "fabric.scrub_",
    "fabric.multichip_",
    "fabric.bitsliced_",
    "fabric.latency_",
    "fabric.deadline_",
    "net.",
    "fleet.",
]


def load(path: str) -> Dict:
    with open(path) as f:
        doc = json.load(f)
    if not isinstance(doc.get("records"), list) or not doc["records"]:
        raise SystemExit(f"FAIL: {path}: empty or missing 'records'")
    return doc


def record_field(doc: Dict, name: str, field: str, path: str) -> float:
    rows = [r for r in doc["records"] if r.get("name") == name]
    if not rows:
        raise SystemExit(f"FAIL: {path}: record {name!r} missing")
    if field not in rows[0]:
        raise SystemExit(
            f"FAIL: {path}: record {name!r} has no field {field!r} "
            f"(fields: {sorted(rows[0])})")
    v = rows[0][field]
    if not isinstance(v, (int, float)) or isinstance(v, bool):
        raise SystemExit(
            f"FAIL: {path}: {name}.{field} is not numeric: {v!r}")
    return float(v)


def check_shape(doc: Dict, path: str) -> None:
    names = {r.get("name", "") for r in doc["records"]}
    for prefix in REQUIRED_PREFIXES:
        if not any(n.startswith(prefix) for n in names):
            raise SystemExit(
                f"FAIL: {path}: no record matches {prefix}* "
                f"(names: {sorted(names)})")
    for key, name, field, *_rest in TRACKED:
        v = record_field(doc, name, field, path)
        if not math.isfinite(v) or v <= 0:
            raise SystemExit(
                f"FAIL: {path}: {key} ({name}.{field}) must be a finite "
                f"positive number, got {v!r}")
    # multichip scaling: events/s must not decrease with chip count
    # (0.75 tolerance factor absorbs timer noise on sub-ms dispatches)
    multi = sorted(
        ((r["chips"], float(r["events_per_s"])) for r in doc["records"]
         if r.get("name", "").startswith("fabric.multichip_")
         and "chips" in r and "events_per_s" in r))
    for (c0, v0), (c1, v1) in zip(multi, multi[1:]):
        if v1 < 0.75 * v0:
            raise SystemExit(
                f"FAIL: {path}: multichip events/s decreases with chip "
                f"count: {c0} chips -> {v0:.0f}/s but {c1} chips -> "
                f"{v1:.0f}/s (tolerance factor 0.75)")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fresh", required=True,
                    help="freshly generated BENCH_fabric.json")
    ap.add_argument("--baseline", required=True,
                    help="committed baseline BENCH_fabric.json")
    ap.add_argument("--tier", choices=["smoke", "nightly"], default="smoke")
    ap.add_argument("--max-drop", type=float, default=0.25,
                    help="nightly: max allowed fractional drop per metric")
    args = ap.parse_args(argv)

    fresh = load(args.fresh)
    baseline = load(args.baseline)

    check_shape(fresh, args.fresh)
    check_shape(baseline, args.baseline)
    print(f"shape OK: {len(fresh['records'])} fresh records, "
          f"{len(baseline['records'])} baseline records")

    if args.tier == "smoke":
        print("smoke tier: key/shape checks only (smoke event counts are "
              "not comparable to the full-size baseline) — PASS")
        return 0

    if fresh.get("smoke"):
        raise SystemExit(
            "FAIL: nightly tier needs full-size numbers but the fresh "
            "document was generated with REPRO_BENCH_SMOKE=1")
    if baseline.get("smoke"):
        raise SystemExit(
            "FAIL: the committed baseline was generated with "
            "REPRO_BENCH_SMOKE=1 — regenerate it full-size (tiny smoke "
            "event counts would make every threshold meaningless)")

    failures = []
    for key, name, field, direction, *rest in TRACKED:
        slack = rest[0] if rest else 1.0
        drift = min(args.max_drop * slack, 0.95)
        got = record_field(fresh, name, field, args.fresh)
        want = record_field(baseline, name, field, args.baseline)
        if direction == "higher":
            bound = want * (1.0 - drift)
            bad = got < bound
            cmp = "<"
        else:   # lower is better: fail on a RISE past the ceiling
            bound = want * (1.0 + drift)
            bad = got > bound
            cmp = ">"
        verdict = "REGRESSED" if bad else "OK"
        print(f"  {key:28s} fresh={got:8.3f}  baseline={want:8.3f}  "
              f"bound={bound:8.3f} ({direction})  {verdict}")
        if bad:
            failures.append(
                f"{key}: {got:.3f} {cmp} {bound:.3f} "
                f"(baseline {want:.3f}, max drift {args.max_drop:.0%})")
    if failures:
        print("FAIL: bench regression gate:\n  " + "\n  ".join(failures))
        return 1
    print("nightly tier: all tracked metrics within "
          f"{args.max_drop:.0%} of baseline — PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
