"""Paper Table 1 + §5 float-model numbers: BDT operating points.

Reproduces: "Before quantization, a background rejection of 4.35% is
achieved for a signal efficiency of 97.53%"; Table 1 (synthesized model):
(96.4, 5.8), (97.8, 3.9), (99.6, 1.1) — our simulated-dataset equivalents
are reported at the same target signal efficiencies.
"""
from __future__ import annotations

import os
import time

import numpy as np

from repro.core.bdt import GradientBoostedClassifier, operating_point_at_signal_eff
from repro.data.smartpixel import SmartPixelConfig, generate, train_test_split

N_EVENTS = 500_000 if os.environ.get("REPRO_BENCH_FULL") else 120_000


def run(emit):
    data = generate(SmartPixelConfig(n_events=N_EVENTS, seed=2024))
    tr, te = train_test_split(data)

    t0 = time.perf_counter()
    clf = GradientBoostedClassifier(
        n_estimators=1, max_depth=5, max_leaf_nodes=10, min_samples_leaf=500
    ).fit(tr["features"], tr["label"])
    fit_us = (time.perf_counter() - t0) * 1e6
    emit("bdt.fit_single_tree_depth5", fit_us, f"n_train={len(tr['label'])}")

    t0 = time.perf_counter()
    score_f = clf.predict_proba(te["features"])
    f_us = (time.perf_counter() - t0) * 1e6 / len(te["label"])
    _, se, br = operating_point_at_signal_eff(score_f, te["label"], 0.9753)
    emit("bdt.float_op@sig_eff_0.9753", f_us,
         f"sig_eff={se:.4f};bkg_rej={br:.4f};paper=0.9753/0.0435")

    q = clf.quantized()
    t0 = time.perf_counter()
    score_q = q.predict_proba(te["features"])
    q_us = (time.perf_counter() - t0) * 1e6 / len(te["label"])
    for target, paper in [(0.964, 0.058), (0.978, 0.039), (0.996, 0.011)]:
        _, se, br = operating_point_at_signal_eff(score_q, te["label"], target)
        emit(f"bdt.table1_quant@sig_eff_{target}", q_us,
             f"sig_eff={se:.4f};bkg_rej={br:.4f};paper_rej={paper}")

    # threshold count / used features (paper: 9 thresholds, 7 inputs)
    t = clf.trees[0]
    emit("bdt.model_complexity", 0.0,
         f"internal_nodes={t.n_internal};used_features={len(t.used_features())};"
         f"paper=9_thresholds_7_inputs")
