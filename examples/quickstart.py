"""Quickstart: the paper's §5 pipeline end-to-end in under a minute.

    PYTHONPATH=src python examples/quickstart.py

simulate smart-pixel sensor -> train a single depth-5 BDT -> quantize to
ap_fixed<28,19> -> synthesize to LUT4s -> place on the 28nm eFPGA ->
encode/decode the bitstream -> classify on the fabric -> verify 100%
against the golden model -> report the data-rate reduction.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.bdt import GradientBoostedClassifier
from repro.core.readout import ReadoutChip
from repro.data.smartpixel import SmartPixelConfig, generate, train_test_split


def main():
    print("== 1. simulate the smart-pixel dataset (reduced: 60k tracks) ==")
    data = generate(SmartPixelConfig(n_events=60_000, seed=2024))
    tr, te = train_test_split(data)
    print(f"   {len(tr['label']):,} train / {len(te['label']):,} test tracks; "
          f"{tr['label'].mean():.1%} pileup")

    print("== 2. train the paper's model: 1 tree, depth 5 ==")
    clf = GradientBoostedClassifier(
        n_estimators=1, max_depth=5, max_leaf_nodes=10, min_samples_leaf=500
    ).fit(tr["features"], tr["label"])
    t = clf.trees[0]
    print(f"   {t.n_internal} thresholds, {len(t.used_features())} inputs used "
          f"(paper: 9 thresholds, 7 inputs)")

    print("== 3. quantize + synthesize + place on the 28nm eFPGA ==")
    chip = ReadoutChip.build(clf, fabric="efpga_28nm")
    cal = chip.calibrate(tr["features"], tr["label"], target_sig_eff=0.97)
    u = chip.config.utilization()
    print(f"   {u['luts']} LUTs of 448 ({u['lut_utilization']:.0%}) "
          f"(paper: 294); bitstream {len(chip.bitstream):,} bytes")
    print(f"   calibrated: sig_eff={cal['signal_efficiency']:.3f} "
          f"bkg_rej={cal['background_rejection']:.3f}")

    print("== 4. run the fabric on the test set (Pallas kernel backend) ==")
    v = chip.verify_vs_golden(te["features"], backend="kernel")
    print(f"   fabric vs golden: {int(v['n_match']):,}/{int(v['n']):,} "
          f"match = {v['accuracy']:.1%} (paper: 100%)")

    rep = chip.data_reduction_report(te["features"], te["label"])
    print(f"== 5. at-source reduction: keep {rep['fraction_kept']:.1%} of hits, "
          f"link {rep['link_rate_in_gbps']:.1f} -> "
          f"{rep['link_rate_out_gbps']:.1f} Gb/s ==")
    assert v["accuracy"] == 1.0
    print("OK — paper §5 reproduced.")


if __name__ == "__main__":
    main()
