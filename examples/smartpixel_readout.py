"""Full §5 reproduction at paper scale: 500k smart-pixel tracks.

    PYTHONPATH=src python examples/smartpixel_readout.py [--events 500000]

Produces every §5 number: float operating point, quantized Table 1,
LUT count vs the 448 capacity, the NN baseline that does NOT fit,
the 100% fabric-vs-golden agreement on the full dataset (via the Pallas
lut_eval kernel), latency, and the streaming (PGPv4-analogue) pipeline.
"""
import argparse
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.bdt import (
    GradientBoostedClassifier, operating_point_at_signal_eff,
)
from repro.core.nn_baseline import MLPSpec, lut_cost, mlp_proba, train_mlp
from repro.core.readout import ReadoutChip
from repro.data.smartpixel import SmartPixelConfig, generate, iter_batches, train_test_split


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--events", type=int, default=500_000)
    ap.add_argument("--seed", type=int, default=2024)
    args = ap.parse_args()

    print(f"generating {args.events:,} tracks ...")
    t0 = time.time()
    data = generate(SmartPixelConfig(n_events=args.events, seed=args.seed))
    tr, te = train_test_split(data)
    print(f"  {time.time()-t0:.1f}s; pileup fraction {data['label'].mean():.3f}")

    print("training the paper's BDT (1 tree, depth 5) ...")
    clf = GradientBoostedClassifier(
        n_estimators=1, max_depth=5, max_leaf_nodes=10, min_samples_leaf=500
    ).fit(tr["features"], tr["label"])

    score_f = clf.predict_proba(te["features"])
    print("\n-- float model (paper: bkg rejection 4.35% @ sig eff 97.53%) --")
    _, se, br = operating_point_at_signal_eff(score_f, te["label"], 0.9753)
    print(f"  closest achievable point: sig_eff={se:.4f} bkg_rej={br:.4f}")

    print("\n-- quantized ap_fixed<28,19> model (paper Table 1) --")
    q = clf.quantized()
    score_q = q.predict_proba(te["features"])
    print("  target | sig_eff | bkg_rej | paper_rej")
    for target, paper in [(0.964, 0.058), (0.978, 0.039), (0.996, 0.011)]:
        _, se, br = operating_point_at_signal_eff(score_q, te["label"], target)
        print(f"  {target:.3f}  | {se:.4f} | {br:.4f} | {paper:.3f}")

    print("\n-- synthesis + fit (paper: 294 LUTs in 448) --")
    chip = ReadoutChip.build(clf, fabric="efpga_28nm")
    u = chip.config.utilization()
    print(f"  BDT: {u['luts']} LUTs, depth {u['depth']}, "
          f"{u['lut_utilization']:.0%} of the 28nm fabric")
    nn = lut_cost(MLPSpec())
    print(f"  NN baseline: {nn['lut_total']} LUTs (paper: >6000) -> does NOT fit")

    print(f"\n-- fabric execution on all {args.events:,} events "
          f"(paper: 100% match vs golden) --")
    t0 = time.time()
    n, n_match = 0, 0
    for lo in range(0, len(te["features"]), 65_536):
        X = te["features"][lo : lo + 65_536]
        v = chip.verify_vs_golden(X, backend="kernel")
        n += int(v["n"])
        n_match += int(v["n_match"])
    # train split too — the paper runs the full 500k
    for lo in range(0, len(tr["features"]), 65_536):
        X = tr["features"][lo : lo + 65_536]
        v = chip.verify_vs_golden(X, backend="kernel")
        n += int(v["n"])
        n_match += int(v["n_match"])
    dt = time.time() - t0
    print(f"  {n_match:,}/{n:,} = {n_match/n:.2%} in {dt:.1f}s "
          f"({n/dt:,.0f} events/s on CPU-interpret kernels)")
    assert n_match == n

    print("\n-- at-source data reduction (40 MHz front-end) --")
    chip.calibrate(tr["features"], tr["label"], target_sig_eff=0.97)
    rep = chip.data_reduction_report(te["features"], te["label"])
    for k, v in rep.items():
        print(f"  {k}: {v:.4g}")

    print("\n-- optional: train the NN that wouldn't fit (accuracy reference) --")
    params, norm, loss = train_mlp(tr["features"][:100_000],
                                   tr["label"][:100_000].astype(np.float32),
                                   steps=150)
    p_nn = mlp_proba(params, norm, te["features"][:50_000])
    _, se, br = operating_point_at_signal_eff(
        p_nn, te["label"][:50_000], 0.978)
    print(f"  NN @ sig_eff~0.978: bkg_rej={br:.4f} "
          f"(better model, but 6000+ LUTs > 448 — the paper's point)")
    print("\nDONE.")


if __name__ == "__main__":
    main()
