"""Streaming front-end readout service (the PGPv4 data-plane analogue).

    PYTHONPATH=src python examples/serve_readout.py [--rate-batches 20]

Simulates the deployed chip's duty cycle: sensor frames stream in batches
(the AXI-Stream/PGPv4 path of §4.2), each batch runs through the configured
eFPGA (Pallas lut_eval backend), and only retained hits go out — with
running link-budget accounting. Reconfiguration mid-stream (a new bitstream
over the SUGOI control plane) swaps the model without stopping the service.
"""
import argparse
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.bdt import GradientBoostedClassifier
from repro.core.readout import ReadoutChip
from repro.data.smartpixel import SmartPixelConfig, generate, iter_batches, train_test_split


def train_chip(seed: int, depth: int, leaves: int, threshold: float = 0.97):
    data = generate(SmartPixelConfig(n_events=60_000, seed=seed))
    tr, _ = train_test_split(data)
    clf = GradientBoostedClassifier(
        n_estimators=1, max_depth=depth, max_leaf_nodes=leaves,
        min_samples_leaf=500,
    ).fit(tr["features"], tr["label"])
    chip = ReadoutChip.build(clf, fabric="efpga_28nm")
    chip.calibrate(tr["features"], tr["label"], target_sig_eff=threshold)
    return chip


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rate-batches", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4_096)
    ap.add_argument("--reconfigure-at", type=int, default=10,
                    help="swap in a new bitstream after N batches")
    args = ap.parse_args()

    chip = train_chip(seed=2024, depth=5, leaves=10)
    print(f"chip online: {chip.config.utilization()['luts']} LUTs, "
          f"bitstream {len(chip.bitstream):,} B")

    stream_cfg = SmartPixelConfig(
        n_events=args.rate_batches * args.batch, seed=777)
    n_in = n_out = 0
    t0 = time.time()
    for i, batch in enumerate(iter_batches(stream_cfg, args.batch)):
        if i == args.reconfigure_at:
            # live reconfiguration: new model, same fabric, no restart
            chip = train_chip(seed=31, depth=4, leaves=8)
            print(f"[batch {i}] RECONFIGURED: new bitstream "
                  f"({chip.config.utilization()['luts']} LUTs) loaded")
        keep = chip.keep_mask(batch["features"], backend="kernel")
        n_in += len(keep)
        n_out += int(keep.sum())
        if (i + 1) % 5 == 0:
            dt = time.time() - t0
            print(f"[batch {i+1:3d}] {n_in/dt:,.0f} hits/s in, kept "
                  f"{n_out/n_in:.1%} -> link out {n_out/dt:,.0f} hits/s")
    print(f"done: {n_in:,} hits in, {n_out:,} out "
          f"(reduction x{n_in/max(n_out,1):.2f}) in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
