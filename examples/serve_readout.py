"""Multi-chip streaming front-end readout service (PGPv4 data-plane analogue).

    PYTHONPATH=src python examples/serve_readout.py [--chips 4] [--features]

Simulates a deployed multi-sensor duty cycle the way the paper deploys it:
RAW charge frames stream in from N sensors (the AXI-Stream/PGPv4 path of
§4.2), each sensor owns a configured eFPGA, and every micro-batch scores
through ONE fused device dispatch (launch/readout_server.py +
kernels/frontend.py): yprofile featurization, ap_fixed quantization,
offset-binary bit packing, banded lut_eval and the keep/drop cut all run
on device with the chip axis sharded — the host never materializes
features or bits. Only retained hits go out, with running link-budget
accounting and a per-stage timing breakdown per dispatch stage.
Mid-stream, one chip is hot-swapped to a new bitstream (the SUGOI
control-plane analogue) — an array swap into the stacked geometry AND the
fused encode plan, no recompile, no service stop.

``--features`` falls back to the legacy host-featurized ingestion
(submit features, host quantize+pack, scoring dispatch) for comparison —
the same stream, two frontends (both shard the chip axis over the
readout mesh).

``--redundancy tmr`` serves every chip as THREE placement-distinct
replica encodings voted 2-of-3 on device (the paper's §5 TMR requirement
as a serving mode); with ``--seu-at N`` the demo injects a
configuration-bit SEU into one replica mid-stream and the stream keeps
scoring bit-exactly while the per-replica disagreement counters — the
SEU health monitor — climb.
``--sparse`` switches the host link to the packed (indices, scores)
trigger format: only keep-flagged events cross it, and the report prints
measured bytes-on-wire vs the dense equivalent.
``--scrub-interval K`` turns on the background scrub task (readback ->
CRC verify -> heal every K dispatches, steered by the disagreement
counters) — the repair leg that makes injected upsets *transient*. It
works WITHOUT redundancy too (CRC-only detection; outputs are exposed
until the heal, which is exactly the window scrubbing bounds).
``--seu-rate R`` keeps faults coming as a Poisson process (R per batch)
so the scrub counters in the final report have something to show. Flag
combinations are validated up front: injecting faults with neither
``--redundancy tmr`` nor ``--scrub-interval`` is refused instead of
silently serving corrupted scores.
``--deadline-us B`` turns on deadline-aware serving: every event gets a
per-event latency budget, and ``--overload-policy`` picks what happens
when the budget is threatened — ``observe`` (count misses only),
``shed`` (admission control rejects at submit, counted per chip) or
``degrade`` (the hysteretic rung ladder: relax scrubbing, CRC-only
scrub, sparse-only egress). The final report prints the latency
percentiles, the met/missed/shed ledger and any ladder transitions.
"""
import argparse
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.bdt import GradientBoostedClassifier
from repro.core.readout import ReadoutChip
from repro.data.pipeline import FrameStream, FrameStreamConfig
from repro.data.smartpixel import SmartPixelConfig, generate, train_test_split
from repro.launch.readout_server import ReadoutServer, ServerConfig


def train_chip(seed: int, depth: int, leaves: int, threshold: float = 0.97):
    data = generate(SmartPixelConfig(n_events=30_000, seed=seed))
    tr, _ = train_test_split(data)
    clf = GradientBoostedClassifier(
        n_estimators=1, max_depth=depth, max_leaf_nodes=leaves,
        min_samples_leaf=500,
    ).fit(tr["features"], tr["label"])
    chip = ReadoutChip.build(clf, fabric="efpga_28nm")
    chip.calibrate(tr["features"], tr["label"], target_sig_eff=threshold)
    return chip


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--chips", type=int, default=4)
    ap.add_argument("--rate-batches", type=int, default=8)
    ap.add_argument("--batch", type=int, default=256,
                    help="events per sensor per stream batch")
    ap.add_argument("--max-batch", type=int, default=8_192,
                    help="server micro-batch size (events, all chips)")
    ap.add_argument("--backend", default="kernel", choices=["kernel", "host"])
    ap.add_argument("--features", action="store_true",
                    help="legacy host-featurized ingestion instead of raw "
                         "frames through the fused frontend")
    ap.add_argument("--reconfigure-at", type=int, default=4,
                    help="hot-swap chip 0's bitstream after N batches")
    ap.add_argument("--redundancy", default="none", choices=["none", "tmr"],
                    help="serve 3 voted replica encodings per chip (SEU "
                         "resilience)")
    ap.add_argument("--sparse", action="store_true",
                    help="sparse trigger readout: only kept events cross "
                         "the host link as packed (indices, scores)")
    ap.add_argument("--seu-at", type=int, default=None,
                    help="inject a config-bit SEU into chip 0 after N "
                         "batches (replica 1 under TMR, the unprotected "
                         "replica 0 otherwise)")
    ap.add_argument("--seu-rate", type=float, default=0.0,
                    help="Poisson configuration-fault rate (faults/batch) "
                         "injected into random replica frames")
    ap.add_argument("--scrub-interval", type=int, default=None,
                    help="background config scrubbing: readback -> CRC "
                         "verify -> heal every K dispatches (off when "
                         "omitted; works without --redundancy via "
                         "CRC-only detection)")
    ap.add_argument("--scrub-mode", default=None,
                    choices=["steered", "round_robin"],
                    help="steer scrubs toward replicas whose disagreement "
                         "counters climb (default), or strict round-robin; "
                         "requires --scrub-interval")
    ap.add_argument("--deadline-us", type=float, default=None,
                    help="per-event latency budget in microseconds "
                         "(deadline-aware serving; off when omitted)")
    ap.add_argument("--overload-policy", default=None,
                    choices=["observe", "shed", "degrade"],
                    help="what to do when the deadline is threatened: "
                         "observe (count only), shed (admission control) "
                         "or degrade (the rung ladder); requires "
                         "--deadline-us")
    args = ap.parse_args()

    # flag-combination validation: fail HERE with a named error instead of
    # silently ignoring a flag (or silently serving corrupted scores)
    if args.seu_rate < 0:
        ap.error("--seu-rate must be >= 0")
    if args.scrub_interval is not None and args.scrub_interval <= 0:
        ap.error("--scrub-interval must be a positive dispatch count")
    if args.scrub_mode is not None and args.scrub_interval is None:
        ap.error("--scrub-mode does nothing without --scrub-interval "
                 "(scrubbing is off)")
    scrub_mode = args.scrub_mode or "steered"
    if ((args.seu_at is not None or args.seu_rate > 0)
            and args.redundancy != "tmr" and args.scrub_interval is None):
        ap.error(
            "--seu-at/--seu-rate need --redundancy tmr (the vote masks "
            "the fault) and/or --scrub-interval (CRC detection heals it); "
            "an unprotected, unscrubbed server would keep serving "
            "corrupted scores")
    if args.deadline_us is not None and args.deadline_us <= 0:
        ap.error("--deadline-us must be a positive latency budget")
    if args.overload_policy is not None and args.deadline_us is None:
        ap.error("--overload-policy does nothing without --deadline-us "
                 "(there is no budget to act on)")
    overload_policy = args.overload_policy or "observe"

    print(f"training {args.chips} chips ...")
    chips = [
        train_chip(seed=2024 + i, depth=5 - (i % 2), leaves=10 - (i % 3))
        for i in range(args.chips)
    ]
    server = ReadoutServer(chips, ServerConfig(
        max_batch=args.max_batch, max_latency_s=50e-3, backend=args.backend,
        redundancy=args.redundancy, sparse=args.sparse,
        scrub_interval=args.scrub_interval, scrub_mode=scrub_mode,
        deadline_us=args.deadline_us, overload_policy=overload_policy))
    geo = server.geometry
    mode = "host-featurized" if args.features else "fused frames"
    extras = []
    if args.redundancy == "tmr":
        extras.append("TMR 2-of-3 vote (3 replica slots/chip)")
    if args.sparse:
        extras.append("sparse trigger link")
    if args.scrub_interval is not None:
        extras.append(f"config scrubbing every {args.scrub_interval} "
                      f"dispatches ({scrub_mode})")
    if args.deadline_us is not None:
        extras.append(f"deadline {args.deadline_us:.0f} us "
                      f"({overload_policy})")
    print(f"server online: {server.n_chips} chips, {mode} ingestion, one "
          f"stacked dispatch (levels={geo.n_levels}, "
          f"widest={geo.max_level_size}, inputs={geo.n_inputs}, "
          f"outputs={geo.n_outputs}, features={geo.frontend.n_features})"
          + (" [" + ", ".join(extras) + "]" if extras else ""))

    stream = FrameStream(FrameStreamConfig(
        n_sensors=args.chips, batch=args.batch))
    seu_rng = np.random.default_rng(2026)
    # monotonic: the server's latency ledger runs on the same clock
    # family, and wall-clock jumps (NTP) must not skew either
    t0 = time.monotonic()
    for bi in range(args.rate_batches):
        if bi == args.reconfigure_at:
            # live reconfiguration: new model into slot 0, stream keeps going
            server.reconfigure(0, train_chip(seed=31, depth=4, leaves=8))
            print(f"[batch {bi}] RECONFIGURED chip 0: new bitstream + encode "
                  "plan swapped into the stack (no recompile)")
        if bi == args.seu_at:
            # radiation strikes: one config bit of one replica flips. The
            # vote masks it (TMR) and/or the scrubber repairs it.
            replica = 1 if args.redundancy == "tmr" else 0
            server.inject_seu(0, replica=replica, lut_index=3, bit=7)
            print(f"[batch {bi}] SEU INJECTED: chip 0 replica {replica}, "
                  "LUT 3 bit 7 — watch the disagreement counters and the "
                  "scrub report")
        for _ in range(seu_rng.poisson(args.seu_rate)):
            slot = int(seu_rng.integers(0, args.chips))
            replica = int(seu_rng.integers(0, server.n_replicas))
            n = server.chips[slot].config.n_luts
            li = int(seu_rng.integers(0, n))
            b = int(seu_rng.integers(0, 16))
            server.inject_seu(slot, replica=replica, lut_index=li, bit=b)
            print(f"[batch {bi}] SEU INJECTED (poisson): chip {slot} "
                  f"replica {replica}, LUT {li} bit {b}")
        for c in range(args.chips):
            block = stream.batch_at(bi, c)
            if args.features:
                server.submit_batch(c, block["features"])
            else:
                server.submit_frames(c, block["frames"], block["y0"])
        server.poll()
        if (bi + 1) % 3 == 0:
            r = server.report()
            print(f"[batch {bi+1:3d}] in={r['n_in']:,} kept="
                  f"{r['fraction_kept']:.1%} queue={r['queue_depth']} "
                  f"inflight={r['inflight_batches']}")
    server.flush()

    r = server.report()
    dt = time.monotonic() - t0
    print(f"\ndone in {dt:.1f}s — {r['n_in']:,} events through "
          f"{r['n_chips']} chips ({r['n_in']/dt:,.0f} ev/s incl. host sim)")
    print("per-stage timing (host-visible seconds / calls):")
    for stage, t in r["stages"].items():
        print(f"  {stage:18s} {t['seconds']:8.3f}s  x{t['calls']}")
    for pc in r["per_chip"]:
        seu = (f", SEU disagreements {pc['seu_disagreements']}"
               if r["redundancy"] == "tmr" else "")
        print(f"  chip {pc['chip']}: kept {pc['fraction_kept']:.1%} "
              f"(x{pc['data_reduction_factor']:.2f} reduction, "
              f"link {pc['link_rate_in_gbps']:.0f} -> "
              f"{pc['link_rate_out_gbps']:.1f} Gb/s, "
              f"{pc['n_dispatches']} dispatches{seu})")
    lb = r["link_bytes"]
    if r["sparse"]:
        print(f"host link: {lb['on_wire']:,} B on the sparse wire vs "
              f"{lb['dense_equivalent']:,} B dense "
              f"(x{lb['wire_reduction']:.2f} reduction)")
    if args.deadline_us is not None:
        dd = r["deadline"]
        lt = r["latency"]["total"]
        print(f"deadline {dd['deadline_us']:.0f} us ({dd['policy']}): "
              f"{dd['met']:,} met / {dd['missed']:,} missed "
              f"({dd['miss_fraction']:.1%}) / {dd['shed']:,} shed — "
              f"latency p50 {lt['p50_us']:.0f} us, p99 {lt['p99_us']:.0f} "
              f"us, p99.9 {lt['p999_us']:.0f} us")
        lad = dd["ladder"]
        if lad["transitions"]:
            steps = ", ".join(
                f"{t['direction']} {t['rung']} (miss {t['miss_frac']:.0%})"
                for t in lad["transitions"])
            print(f"degrade ladder: level {lad['level']} "
                  f"[{', '.join(lad['active_rungs']) or 'none'}] — {steps}")
    sc = r["scrub"]
    if sc["enabled"]:
        lat = sc["detection_latency_dispatches"]
        print(f"scrubbing ({sc['mode']}, every {sc['interval']} "
              f"dispatches): {sc['frames_scrubbed']} frames scrubbed in "
              f"{sc['steps']} steps ({sc['cycles']} full cycles), "
              f"{sc['detections']} upsets detected, {sc['healed_bits']} "
              f"config bits healed, detection latency mean "
              f"{lat['mean']:.1f} / max {lat['max']} dispatches")


if __name__ == "__main__":
    main()
