"""Serve a small LM with batched requests through the KV-cache decode path
(the serve_step that the decode_32k / long_500k dry-run cells lower).

    PYTHONPATH=src python examples/serve_lm.py
"""
import os
import subprocess
import sys


def main():
    args = [
        sys.executable, "-m", "repro.launch.serve",
        "--preset", "tiny", "--batch", "8", "--prompt-len", "16", "--gen", "48",
    ] + sys.argv[1:]
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    raise SystemExit(subprocess.call(args, env=env))


if __name__ == "__main__":
    main()
