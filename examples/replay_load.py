"""Replay recorded sensor frames against a live network front door.

    PYTHONPATH=src python examples/replay_load.py
    PYTHONPATH=src python examples/replay_load.py \
        --sensors 4 --rate 5000 --pattern square --batches 32

Builds the paper's single-tree readout chip per sensor, starts the
asyncio front door (TCP + UDP) on loopback, then drives one replay
client PER SENSOR concurrently — each streams deterministic
``FrameStream`` frames at a controlled Poisson or square-wave event
rate, collects the sparse trigger decisions coming back, and verifies
every one bit-exact against the host oracle. Prints per-sensor achieved
rate + end-to-end latency percentiles and the door's per-client
accounting (``report()["net"]``).

``--rate 0`` floods unpaced (the loopback-throughput configuration);
see ``benchmarks/bench_net.py`` for the calibrated comparison against
the in-process rate.
"""
import argparse
import asyncio
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def build_chip(seed: int = 5):
    from repro.core.bdt import GradientBoostedClassifier
    from repro.core.readout import ReadoutChip
    from repro.data.smartpixel import (
        SmartPixelConfig, generate, train_test_split)

    data = generate(SmartPixelConfig(n_events=8_000, seed=seed))
    tr, _ = train_test_split(data)
    clf = GradientBoostedClassifier(
        n_estimators=1, max_depth=5, max_leaf_nodes=10,
        min_samples_leaf=500,
    ).fit(tr["features"], tr["label"])
    chip = ReadoutChip.build(clf)
    chip.calibrate(tr["features"], tr["label"], target_sig_eff=0.95)
    return chip


async def main_async(args):
    from repro.data.pipeline import FrameStream, FrameStreamConfig
    from repro.launch.readout_server import ReadoutServer, ServerConfig
    from repro.net.ingress import FrontDoorConfig, ReadoutFrontDoor
    from repro.net.replay import (
        ReplayConfig, frame_stream_source, host_oracle, replay)

    print(f"== building {args.sensors} chip(s) ==")
    chip = build_chip()
    chips = [chip] * args.sensors
    srv = ReadoutServer(chips, ServerConfig(
        max_batch=256, max_latency_s=5e-3, backend=args.backend,
        batch_tile=128))
    door = ReadoutFrontDoor(srv, FrontDoorConfig())
    await door.start()
    print(f"== front door up: tcp={door.tcp_port} udp={door.udp_port} ==")

    stream = FrameStream(FrameStreamConfig(
        n_sensors=args.sensors, batch=max(args.events_per_batch, 8),
        seed=702))
    oracle = host_oracle(chip)

    async def one_sensor(sensor: int):
        cfg = ReplayConfig(
            rate_hz=args.rate, pattern=args.pattern,
            n_batches=args.batches,
            events_per_batch=args.events_per_batch, sensor=sensor,
            transport=args.transport, seed=11 + sensor)
        src = frame_stream_source(stream, sensor, args.events_per_batch)
        return await replay("127.0.0.1", door.tcp_port
                            if args.transport == "tcp" else door.udp_port,
                            src, cfg, oracle)

    try:
        reports = await asyncio.gather(
            *(one_sensor(s) for s in range(args.sensors)))
    finally:
        await door.stop()

    ok = True
    for s, rep in enumerate(reports):
        lat = rep.latency
        print(f"sensor {s}: {rep.n_events} events @ "
              f"{rep.achieved_ev_s:,.0f} ev/s  "
              f"p50={lat['p50_us'] / 1e3:.2f}ms "
              f"p99={lat['p99_us'] / 1e3:.2f}ms  "
              f"kept={rep.n_kept}/{rep.n_triggers}  "
              f"verified={rep.verified}")
        if rep.mismatches:
            ok = False
            print(f"  MISMATCHES: {rep.mismatches[:3]}")
    net = srv.report()["net"]
    print("== door accounting ==")
    print(json.dumps(net, indent=2, sort_keys=True, default=int))
    if not ok:
        raise SystemExit("trigger decisions did NOT match the host oracle")
    print("all trigger decisions bit-exact vs the host oracle")


def main():
    ap = argparse.ArgumentParser(
        description="replay load generator for the readout front door")
    ap.add_argument("--sensors", type=int, default=2)
    ap.add_argument("--rate", type=float, default=2_000.0,
                    help="target events/s per sensor (0 = unpaced)")
    ap.add_argument("--pattern", default="poisson",
                    choices=["poisson", "square"])
    ap.add_argument("--batches", type=int, default=24)
    ap.add_argument("--events-per-batch", type=int, default=16)
    ap.add_argument("--transport", default="tcp", choices=["tcp", "udp"])
    ap.add_argument("--backend", default="host",
                    choices=["host", "kernel"])
    args = ap.parse_args()
    if args.transport == "udp":
        from repro.net import protocol as P
        args.events_per_batch = min(args.events_per_batch,
                                    P.UDP_MAX_EVENTS)
    asyncio.run(main_async(args))


if __name__ == "__main__":
    main()
