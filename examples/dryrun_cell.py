"""Lower + compile one (arch x shape) cell on the production mesh and print
its roofline terms — the per-cell view of the multi-pod dry-run.

    PYTHONPATH=src python examples/dryrun_cell.py --arch gemma-7b \
        --shape train_4k --mesh single
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-7b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    args = ap.parse_args()

    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count=512 "
        + os.environ.get("XLA_FLAGS", ""))
    from benchmarks.roofline import analyze
    from repro.launch.dryrun import lower_cell
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=args.mesh == "multi")
    _, compiled, summary = lower_cell(
        args.arch, args.shape, mesh,
        "multi_pod_2x16x16" if args.mesh == "multi" else "single_pod_16x16")
    r = analyze(summary)
    print(f"\n{args.arch} x {args.shape} on {r['mesh']} ({r['n_devices']} chips)")
    print(f"  compute    {r['compute_s']:.3e} s")
    print(f"  memory     {r['memory_s']:.3e} s")
    print(f"  collective {r['collective_s']:.3e} s")
    print(f"  bottleneck: {r['bottleneck']}   roofline_frac: "
          f"{r['roofline_frac']:.3f}   usefulness: {r['usefulness']:.2f}")
    print(f"  peak HBM/chip: {r['peak_gib']:.2f} GiB  fits: {r['fits_hbm']}")


if __name__ == "__main__":
    main()
