"""Train a small LM end-to-end with the framework's training substrate
(optimizer, deterministic pipeline, atomic checkpoints, resume).

    PYTHONPATH=src python examples/train_lm.py            # ~15M params, 200 steps
    PYTHONPATH=src python examples/train_lm.py --steps 50 # shorter

The corpus is a fixed random Markov chain (entropy bound log(4) = 1.386
nats), so the loss visibly converges toward a known floor — proof the whole
substrate trains, not just runs. Kill it mid-run and re-invoke with
--resume to see checkpoint restart.
"""
import os
import subprocess
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main():
    args = [
        sys.executable, "-m", "repro.launch.train",
        "--preset", "tiny", "--steps", "200", "--batch", "16", "--seq", "128",
        "--lr", "2e-3", "--ckpt-dir", "checkpoints/example_lm",
        "--ckpt-every", "50", "--resume",
    ] + sys.argv[1:]
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    raise SystemExit(subprocess.call(args, env=env))


if __name__ == "__main__":
    main()
